"""Ullmann-refined Particle Swarm Optimization for subgraph matching.

Faithful implementation of paper Algorithm 1. Each particle carries a
continuously-relaxed mapping S ∈ [0,1]^{n×m} (row-stochastic, masked by the
global compatibility Mask). Per epoch:

  1. InitParticles          — fresh swarm (global bests persist across epochs)
  2. K inner steps          — ONE fused launch through the backend seam
                              (KernelBackend.epoch_fused): velocity/position/
                              mask/normalize update, optional requantize,
                              fitness -‖Q-SGSᵀ‖², local & global best
                              tracking — particle state stays kernel-resident
                              for the whole epoch on the Pallas path
  3. Projection             — greedy argmax assignment M̃ (comparator tree)
  4. UllmannRefine          — candidate set from S ∪ M̃, matrix-form pruning
                              sweeps, re-projection → M̂
  5. IsFeasible             — M̂ G M̂ᵀ ⊇ Q and injectivity
  6. EliteConsensus         — S̄ = softmax-weighted elite average (the global
                              controller's consensus-guided direction)

Everything is vmapped over particles and jit-compiled; the epoch loop is a
``lax.scan`` so the whole matcher is a single XLA program (this is what the
dry-run lowers onto the production mesh).

Quantized mode (paper §3.4): S is re-quantized to uint8 after every update
(straight-through), fitness runs on the int8/int32 MAC path, and row
renormalization uses the divide-free reciprocal-multiply model.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels import backend as kernel_backend


@dataclasses.dataclass(frozen=True)
class PSOConfig:
    """Static configuration of Algorithm 1 (one frozen value per knob).

    Every field is trace-static: two configs that differ in ANY field
    compile (and AOT-cache, and snapshot-validate) as different
    programs — ``kernels.backend.config_digest`` hashes all of them, so
    the service's persisted executables and warm-state snapshots are
    automatically invalidated by a config drift. Fields are documented
    inline below; swarm-shape fields (``num_particles``/``epochs``/
    ``inner_steps``) set array shapes, the float knobs are baked-in
    constants, and the ``backend``/``quantized``/``prune_mask``/
    ``early_exit`` family selects which kernels the traced program
    calls.
    """
    num_particles: int = 64          # N (per device in the sharded matcher)
    epochs: int = 4                  # T
    inner_steps: int = 12            # K
    omega: float = 0.7               # inertia
    c1: float = 1.4                  # cognitive (S_local)
    c2: float = 1.4                  # social (S*)
    c3: float = 0.6                  # consensus (S̄) — the paper's addition
    v_max: float = 0.5               # velocity clamp per S entry
    elite_frac: float = 0.25         # top-k fraction fused into S̄
    consensus_temp: float = 25.0     # softmax temperature on normalized f
    refine_threshold: float = 0.5    # S ≥ τ·rowmax(S) enters the candidate set
    refine_iters: int = 6            # Ullmann pruning sweeps
    quantized: bool = False          # uint8 S + int32-MAC fitness (§3.4)
    backend: str = "auto"            # KernelBackend registry name
                                     # ("ref" | "pallas" | "interpret");
                                     # "auto" defers to the
                                     # REPRO_KERNEL_BACKEND env var, then
                                     # the platform default
    prune_mask: bool = True          # global Ullmann+injectivity pre-prune
    prune_iters: int = 0             # 0 = iterate the pre-prune to fixpoint
    early_exit: bool = False         # stop epochs once a good mapping exists
    early_exit_fitness: float = float("-inf")   # "good" = feasible ∧ f ≥ this
    carry_fastpath: bool = True      # with early_exit: verify the warm
                                     # carry's S* by one projection and skip
                                     # every epoch if it is still feasible
    gumbel_tau: float = 0.0          # >0: per-particle Gumbel-perturbed
                                     # structured projection (diversity after
                                     # consensus collapse; off by default)

    def replace(self, **kw) -> "PSOConfig":
        return dataclasses.replace(self, **kw)


class SwarmState(dict):
    """Light pytree: S, V, S_local, f_local, S_star, f_star, S_bar."""


def init_particles(key: jax.Array, num: int, mask: jax.Array):
    """Random masked row-stochastic mappings + zero velocities."""
    n, m = mask.shape
    u = jax.random.uniform(key, (num, n, m), minval=0.05, maxval=1.0)
    s = u * mask.astype(jnp.float32)[None]
    row = s.sum(-1, keepdims=True)
    mask_rows = mask.astype(jnp.float32).sum(-1, keepdims=True)[None]
    uniform = mask.astype(jnp.float32)[None] / jnp.maximum(mask_rows, 1.0)
    s = jnp.where(row > 1e-9, s / jnp.maximum(row, 1e-9), uniform)
    v = jnp.zeros_like(s)
    return s, v


def _fitness(S, Q, G, cfg: PSOConfig):
    bk = kernel_backend.for_config(cfg)
    if cfg.quantized:
        Sq = bk.quantize_s(S)
        f = bk.edge_fitness_quantized(Sq, Q, G)
        return f / (255.0 ** 4)   # rescale to float-fitness units
    return bk.edge_fitness(S, Q, G)


def _maybe_requantize(S, mask, cfg: PSOConfig):
    """Straight-through uint8 re-quantization of the swarm state (models the
    accelerator keeping S resident in uint8 between steps)."""
    if not cfg.quantized:
        return S
    bk = kernel_backend.for_config(cfg)
    Sq = jax.vmap(bk.row_normalize_quantized, in_axes=(0, None))(
        bk.quantize_s(S), mask)
    return bk.dequantize_s(Sq)


def elite_k_for(cfg: PSOConfig) -> int:
    """Static elite count k = max(1, round(elite_frac · N)) (line 24)."""
    return max(1, int(round(cfg.elite_frac * cfg.num_particles)))


def elite_consensus(S_all, f_all, cfg: PSOConfig):
    """S̄: softmax-weighted average of the elite fraction (paper line 24).

    Also returns (weighted_sum, weight_total) so the distributed matcher can
    psum the parts across devices before dividing. Thin wrapper over the
    backend seam (``KernelBackend.elite_consensus``) — the fused epoch
    tail computes the same reduction in-kernel.
    """
    bk = kernel_backend.for_config(cfg)
    k = max(1, int(round(cfg.elite_frac * S_all.shape[0])))
    return bk.elite_consensus(S_all, f_all, elite_k=k,
                              consensus_temp=cfg.consensus_temp)


def ullmann_refine_candidates(S, M_proj, Q, G, mask, cfg: PSOConfig):
    """Paper line 20: refine the particle's candidate structure with Ullmann
    pruning sweeps, then re-project. Batched over particles. Thin wrapper
    over the backend seam (``KernelBackend.ullmann_refine_candidates``) —
    the fused epoch tail runs the same refinement in-kernel."""
    bk = kernel_backend.for_config(cfg)
    return bk.ullmann_refine_candidates(
        S, M_proj, Q, G, mask, refine_threshold=cfg.refine_threshold,
        refine_iters=cfg.refine_iters)


def _epoch_start(carry, key, Q, G, mask, cfg: PSOConfig):
    """Epoch prologue (one problem): key splits, fresh swarm, initial
    fitness, global-best seeding, and the pre-drawn per-step randoms.

    The key-split topology is exactly the pre-fusion ``run_epoch``'s
    (3-way with gumbel, else 2-way), and ``r_all[k]`` equals the
    ``uniform(split(k_steps, K)[k], (N, 3))`` draw the legacy inner
    scan made at step k — hoisting the draws out of the loop is what
    lets the fused kernel consume the identical random stream.
    """
    S_star, f_star, _ = carry
    if cfg.gumbel_tau > 0:
        k_init, k_steps, k_gum = jax.random.split(key, 3)
    else:
        k_init, k_steps = jax.random.split(key)
        k_gum = key   # unused: cfg.gumbel_tau == 0 never draws from it
    S, V = init_particles(k_init, cfg.num_particles, mask)
    f_local = _fitness(S, Q, G, cfg)

    # seed global best from the fresh swarm if better
    best0 = jnp.argmax(f_local)
    better0 = f_local[best0] > f_star
    S_star = jnp.where(better0, S[best0], S_star)
    f_star = jnp.where(better0, f_local[best0], f_star)

    step_keys = jax.random.split(k_steps, cfg.inner_steps)
    r_all = jax.vmap(
        lambda k: jax.random.uniform(k, (cfg.num_particles, 3)))(step_keys)
    return S, V, f_local, S_star, f_star, r_all, k_gum


def _epoch_finish(S, S_star, f_star, f_trace, f_final, k_gum, Q, G, mask,
                  cfg: PSOConfig):
    """Epoch epilogue (one problem): projections, Ullmann refinement,
    feasibility, elite consensus — everything downstream of the fused
    inner loop, as ONE ``KernelBackend.epoch_finish`` launch. Returns
    the ``(carry, outs)`` pair ``run_epoch`` has always returned.

    ``f_final`` is the fused epoch kernel's last-step per-particle
    fitness (already in ``_fitness``'s scaled float units on both the
    float and quantized paths) threaded through instead of recomputed —
    the pre-fusion epilogue paid a full ``_fitness(S)`` launch for
    values the inner loop had just produced, bitwise-identically
    (``tests/test_backend.py::test_run_epoch_bitwise_equals_legacy_scan``).

    Two complementary projections are tried per particle:
      (a) adjacency-guided constructive (structured_project) — wins on
          sparse engine meshes where structure-blind argmax almost never
          lands on a consistent sub-DAG; optionally Gumbel-perturbed
          (τ-scaled noise on log S makes the constructive argmax a
          per-row softmax sample, so consensus-collapsed particles
          explore distinct assignments; τ=0 is exact deterministic
          projection);
      (b) plain greedy argmax + Ullmann candidate refinement — wins on
          dense targets where the constructive greedy can dead-end.
    """
    bk = kernel_backend.for_config(cfg)
    # The Gumbel field is the one random input of the epilogue; drawing
    # it host-side (same key, same shape, same dtype as the pre-fusion
    # code) keeps the kernel deterministic AND the RNG stream bitwise
    # identical to the legacy epilogue.
    if cfg.gumbel_tau > 0:
        gum = jax.random.gumbel(k_gum, S.shape, dtype=jnp.float32)
    else:
        gum = None
    M_hat, feasible, S_bar = bk.epoch_finish(
        S, f_final, gum, mask, Q, G, gumbel_tau=cfg.gumbel_tau,
        refine_threshold=cfg.refine_threshold,
        refine_iters=cfg.refine_iters, elite_k=elite_k_for(cfg),
        consensus_temp=cfg.consensus_temp)

    out = dict(mappings=M_hat, feasible=feasible, fitness=f_final,
               f_star_trace=f_trace, S_final=S)
    return (S_star, f_star, S_bar), out


def run_epoch(carry, key, Q, G, mask, cfg: PSOConfig):
    """One epoch of Algorithm 1 for a local swarm. carry holds the global
    controller state (S*, f*, S̄) persisted across epochs.

    The whole epoch is TWO kernel launches with no host-visible
    intermediates: the K-step inner loop through the seam's fused epoch
    kernel (``KernelBackend.epoch_fused`` — particle state VMEM-resident
    for the whole epoch on the Pallas path), then the entire epilogue
    (projections, Ullmann refinement, feasibility, elite consensus)
    through the fused tail (``KernelBackend.epoch_finish``). The ``ref``
    path is the original loose code, bitwise-equal
    (``tests/test_backend.py``).
    """
    bk = kernel_backend.for_config(cfg)
    S_bar = carry[2]
    S, V, f_local, S_star, f_star, r_all, k_gum = _epoch_start(
        carry, key, Q, G, mask, cfg)
    S, S_star, f_star, f_trace, f_last = bk.epoch_fused(
        S, V, S, f_local, S_star, f_star, S_bar, mask, Q, G, r_all,
        omega=cfg.omega, c1=cfg.c1, c2=cfg.c2, c3=cfg.c3,
        v_max=cfg.v_max, quantized=cfg.quantized)
    return _epoch_finish(S, S_star, f_star, f_trace, f_last, k_gum, Q, G,
                         mask, cfg)


def run_epoch_batch(carry, keys, Qb, Gb, maskb, cfg: PSOConfig):
    """Problem-batched ``run_epoch``: P problems, one fused-epoch launch.

    Equivalent to ``vmap(run_epoch)`` over the leading problem axis —
    the prologue is literally that vmap — but both the inner loop
    (``KernelBackend.epoch_fused_batch``) and the entire epilogue
    (``KernelBackend.epoch_finish_batch``) go through problem-gridded
    kernels, so one epoch over P problems is exactly two launches.
    Used by ``match_batch`` and the problem-sharded mesh matcher.
    """
    bk = kernel_backend.for_config(cfg)
    S_bar_b = carry[2]
    S, V, f_local, S_star, f_star, r_all, k_gum = jax.vmap(
        lambda c, k, Q, G, mk: _epoch_start(c, k, Q, G, mk, cfg)
    )(carry, keys, Qb, Gb, maskb)
    S, S_star, f_star, f_trace, f_last = bk.epoch_fused_batch(
        S, V, S, f_local, S_star, f_star, S_bar_b, maskb, Qb, Gb, r_all,
        omega=cfg.omega, c1=cfg.c1, c2=cfg.c2, c3=cfg.c3,
        v_max=cfg.v_max, quantized=cfg.quantized)
    f_final = f_last
    # Per-problem Gumbel fields, drawn from the same per-problem keys the
    # single-problem path uses so batch ≡ vmap(run_epoch) stays bitwise.
    if cfg.gumbel_tau > 0:
        gum = jax.vmap(
            lambda k, s: jax.random.gumbel(k, s.shape, dtype=jnp.float32)
        )(k_gum, S)
    else:
        gum = None
    M_hat, feasible, S_bar = bk.epoch_finish_batch(
        S, f_final, gum, maskb, Qb, Gb, gumbel_tau=cfg.gumbel_tau,
        refine_threshold=cfg.refine_threshold,
        refine_iters=cfg.refine_iters, elite_k=elite_k_for(cfg),
        consensus_temp=cfg.consensus_temp)
    out = dict(mappings=M_hat, feasible=feasible, fitness=f_final,
               f_star_trace=f_trace, S_final=S)
    return (S_star, f_star, S_bar), out


def default_carry(mask: jax.Array):
    """Cold-start controller state: uniform S̄ over the mask, no best yet.

    This is what every ``match`` call used before warm-starting existed;
    the online service replaces it with the previous epoch's consensus for
    repeat (workload, platform-state) arrivals.
    """
    maskf = mask.astype(jnp.float32)
    mask_rows = maskf.sum(-1, keepdims=True)
    S_bar0 = maskf / jnp.maximum(mask_rows, 1.0)
    return (S_bar0, jnp.float32(-jnp.inf), S_bar0)


def carry_fast_path(carry0, Q, G, mask, cfg: PSOConfig):
    """Trust-but-verify the warm-start carry (§warm starts, microsecond
    decisions): project the carried global best S* once and, if the result
    is still a feasible mapping of this problem, the whole epoch scan can
    be skipped — the previous decision is simply re-validated at the cost
    of ONE structured projection instead of a swarm launch.

    The cold prior (f* = -inf) never fast-paths, so cold calls are
    bit-identical with or without the flag. Returns ``(M_c, ok)``.
    """
    bk = kernel_backend.for_config(cfg)
    S_star0, f_star0, _ = carry0
    M_c = bk.structured_project(S_star0, Q, G, mask).astype(jnp.uint8)
    ok = (bk.is_feasible(M_c, Q, G)
          & (f_star0 > jnp.float32(-jnp.inf))
          & (f_star0 >= cfg.early_exit_fitness))
    return M_c, ok


def rebase_carry(carry, mask: jax.Array):
    """Project a stored controller carry onto a (possibly different)
    compatibility mask.

    The similarity-keyed carry store (service Tier 1) reuses the carry of
    the *nearest* platform state when the free-engine set has drifted:
    S* and S̄ are masked to the new compatibility mask and row-renormalized
    (rows whose support vanished fall back to uniform over the new mask).
    Row renormalization is a positive per-row scale, so for an *identical*
    mask the rebase is exactly the identity on any swarm-produced carry —
    Tier 0 and Tier 1 can therefore share one revalidation kernel.

    f* is passed through untouched: it is only ever used as a "this carry
    holds a real decision" gate (> -inf); fitness values are not
    comparable across platform states, so the caller decides what f to
    store after revalidation (see ``revalidate_carry``).
    """
    S_star, f_star, S_bar = carry
    maskf = mask.astype(jnp.float32)
    mask_rows = maskf.sum(-1, keepdims=True)
    uniform = maskf / jnp.maximum(mask_rows, 1.0)

    def onto(S):
        S = S.astype(jnp.float32) * maskf
        row = S.sum(-1, keepdims=True)
        return jnp.where(row > 1e-9, S / jnp.maximum(row, 1e-9), uniform)

    return onto(S_star), f_star, onto(S_bar)


def revalidate_carry(carry0, Q, G, mask, cfg: PSOConfig):
    """Tier-0/1 decision kernel: rebase + ONE masked structured projection.

    The batched pipeline's cheap stage: the carry is rebased onto this
    problem's (pruned) mask, its S* is projected once, and the projection
    is feasibility-checked against the *actual* Q/G — a rebased carry can
    therefore never yield an infeasible mapping marked found. Also
    computes the projected mapping's own fitness ``f_c`` on THIS problem
    (the stored f* is not transferable across platform states), which the
    service stores back on a Tier-1 hit.

    Returns ``dict(mapping, ok, ok_rebase, fitness, S_star, S_bar)``:
    ``ok`` is the Tier-0 verdict (carried-f* gate, bit-compatible with
    ``carry_fast_path``), ``ok_rebase`` the stricter Tier-1 verdict
    (also requires the projection's own fitness to clear the bound), and
    S_star/S_bar are the rebased controller state (f* intentionally
    omitted: hits store ``fitness``, swarm seeds reset it to -inf).
    """
    bk = kernel_backend.for_config(cfg)
    S_rb, f_star0, S_bar_rb = rebase_carry(carry0, mask)
    M_c = bk.structured_project(S_rb, Q, G, mask).astype(jnp.uint8)
    f_c = _fitness(M_c.astype(jnp.float32)[None], Q, G, cfg)[0]
    # ``ok`` gates on the CARRIED f* exactly like the in-kernel
    # ``carry_fast_path``, so Tier-0 batch revalidation and a single
    # warm ``match`` agree at any ``early_exit_fitness`` threshold.
    ok = (bk.is_feasible(M_c, Q, G)
          & (f_star0 > jnp.float32(-jnp.inf))
          & (f_star0 >= cfg.early_exit_fitness))
    # Tier 1 must not trust a fitness measured on a different platform
    # state: a REBASED carry additionally clears the bound with the
    # projection's own fitness on THIS problem.
    ok_rebase = ok & (f_c >= cfg.early_exit_fitness)
    return dict(mapping=M_c, ok=ok, ok_rebase=ok_rebase, fitness=f_c,
                S_star=S_rb, S_bar=S_bar_rb)


def _revalidate_batch_body(Qb: jax.Array, Gb: jax.Array, maskb: jax.Array,
                           cfg: PSOConfig, carry0):
    """Batched revalidation: B carries re-validated in one launch, no
    epochs — one projection + feasibility check per problem. Masks are
    pre-pruned exactly as ``_match_batch_body`` does, so the projection
    sees the same candidate sets the swarm that produced the carry saw."""
    B = maskb.shape[0]
    bk = kernel_backend.for_config(cfg)
    if cfg.prune_mask:
        maskb, prune_sweeps = bk.prune_fixpoint_batch(maskb, Qb, Gb,
                                                      cfg.prune_iters)
    else:
        prune_sweeps = jnp.zeros((B,), jnp.int32)
    outs = jax.vmap(
        lambda c, Q, G, mk: revalidate_carry(c, Q, G, mk, cfg)
    )(carry0, Qb, Gb, maskb)
    outs["prune_sweeps"] = prune_sweeps
    # echo the carried f* through the launch: the service reads the
    # stored-fitness of Tier-0 hits from the (single) batched output
    # fetch instead of a per-item host sync, and the echo stays valid
    # even when the stacked carry input buffers were donated to XLA
    outs["f_carry"] = jnp.asarray(carry0[1], jnp.float32)
    return outs


_revalidate_batch_impl = functools.partial(
    jax.jit, static_argnames=("cfg",))(_revalidate_batch_body)


def revalidate_batch(Qb: jax.Array, Gb: jax.Array, maskb: jax.Array,
                     cfg: PSOConfig, carry0):
    """Tier-0 pipeline entry point: batch-revalidate B stored carries.

    Inputs are stacked on a leading problem axis like ``match_batch``;
    ``carry0`` holds the per-problem carries to re-validate (exact warm
    carries for Tier 0, nearest-neighbour carries for Tier 1 — the rebase
    inside makes both cases one kernel). Returns a pytree of
    ``mapping`` (B, n, m) uint8, ``ok`` (B,) bool, ``fitness`` (B,) f32,
    the rebased ``S_star``/``S_bar``, and ``f_carry`` (B,) f32 — the
    carried f* echoed through the launch so callers can read it from the
    output fetch even after donating the carry input buffers. Cost is
    one jit dispatch and one projection per problem — no swarm, no
    epochs.
    """
    return _revalidate_batch_impl(Qb, Gb, maskb, cfg, carry0)


def _skip_epoch_outs(carry, n, m, cfg: PSOConfig):
    """Shape-matched placeholder outputs for an early-exited epoch."""
    _, f_star, _ = carry
    return dict(
        mappings=jnp.zeros((cfg.num_particles, n, m), jnp.uint8),
        feasible=jnp.zeros((cfg.num_particles,), bool),
        fitness=jnp.full((cfg.num_particles,), -jnp.inf, jnp.float32),
        f_star_trace=jnp.full((cfg.inner_steps,), f_star, jnp.float32))


def epoch_found(outs, cfg: PSOConfig) -> jax.Array:
    """Early-exit predicate: some particle projected to a feasible mapping
    whose fitness clears the bound."""
    return jnp.any(outs["feasible"]
                   & (outs["fitness"] >= cfg.early_exit_fitness))


def scan_epochs(run_one, carry0, keys, n, m, cfg: PSOConfig,
                all_found=None, done0=None):
    """Scan ``run_one(carry, k) -> (carry, outs)`` over the epoch keys,
    optionally gated by ``cfg.early_exit`` (skipped epochs cost one
    predicated branch and emit shape-matched empty outputs).

    ``run_one`` must drop the ``S_final`` entry from its outputs.
    ``all_found`` (distributed matcher) fuses the local found-predicate
    across the mesh so every shard takes the same branch — the predicate
    must be replicated or the collectives inside ``run_one`` deadlock.
    ``done0`` pre-marks the problem as solved before any epoch runs (the
    warm-carry fast path); it must likewise be replicated.

    Returns ``(carry, outs, epochs_run)``.
    """
    if not cfg.early_exit:
        carry, outs = jax.lax.scan(run_one, carry0, keys)
        return carry, outs, jnp.int32(cfg.epochs)

    def epoch_step(state, k):
        carry, done_prev, n_run = state

        def live(_):
            return run_one(carry, k)

        def skip(_):
            return carry, _skip_epoch_outs(carry, n, m, cfg)

        carry2, outs = jax.lax.cond(done_prev, skip, live, None)
        found = epoch_found(outs, cfg)
        if all_found is not None:
            found = all_found(found)
        done = done_prev | found
        n_run = n_run + (~done_prev).astype(jnp.int32)
        return (carry2, done, n_run), outs

    state0 = (carry0,
              jnp.bool_(False) if done0 is None else done0,
              jnp.int32(0))
    (carry, _, epochs_run), outs = jax.lax.scan(epoch_step, state0, keys)
    return carry, outs, epochs_run


def _match_body(key: jax.Array, Q: jax.Array, G: jax.Array, mask: jax.Array,
                cfg: PSOConfig, carry0):
    n, m = mask.shape
    if cfg.prune_mask:
        mask, prune_sweeps = kernel_backend.for_config(cfg).prune_fixpoint(
            mask, Q, G, cfg.prune_iters)
    else:
        prune_sweeps = jnp.int32(0)
    keys = jax.random.split(key, cfg.epochs)

    if cfg.early_exit and cfg.carry_fastpath:
        M_c, carry_ok = carry_fast_path(carry0, Q, G, mask, cfg)
    else:
        M_c = jnp.zeros((n, m), jnp.uint8)
        carry_ok = jnp.bool_(False)

    def run_one(carry, k):
        carry, outs = run_epoch(carry, k, Q, G, mask, cfg)
        del outs["S_final"]  # only needed by the distributed consensus
        return carry, outs

    (S_star, f_star, S_bar), outs, epochs_run = scan_epochs(
        run_one, carry0, keys, n, m, cfg, done0=carry_ok)
    outs["S_star"] = S_star
    outs["f_star"] = f_star
    outs["S_bar"] = S_bar
    outs["epochs_run"] = epochs_run
    outs["carry_mapping"] = M_c
    outs["carry_feasible"] = carry_ok
    outs["prune_sweeps"] = prune_sweeps
    return outs


# ---------------------------------------------------------------------------
# Batched problem axis B (coalesced concurrent arrivals)
# ---------------------------------------------------------------------------

def default_carry_batch(maskb: jax.Array):
    """Cold controller state for a stacked (B, n, m) mask batch."""
    return jax.vmap(default_carry)(maskb)


def scan_epochs_batch(run_one, carry0, keys, n, m, cfg: PSOConfig,
                      done0=None):
    """Batched-problem variant of ``scan_epochs``.

    ``run_one(carry_b, keys_b) -> (carry_b, outs_b)`` runs one epoch for
    every problem in the batch (all leaves carry a leading problem axis B;
    ``keys`` is (T, B) epoch keys). Early exit is *per problem*: a problem
    that already found a mapping has its carry frozen and its outputs
    replaced by the shape-matched skip placeholders — exactly what the
    single-problem ``scan_epochs`` skip branch produces — so one finished
    problem never stalls or perturbs the rest of the batch. Whole-batch
    compute is only skipped (one predicated branch) once *every* problem
    is done.

    Returns ``(carry, outs, epochs_run)`` with ``epochs_run`` shaped (B,).
    """
    B = jax.tree_util.tree_leaves(carry0)[0].shape[0]
    if not cfg.early_exit:
        carry, outs = jax.lax.scan(run_one, carry0, keys)
        return carry, outs, jnp.full((B,), cfg.epochs, jnp.int32)

    skip_outs_b = jax.vmap(lambda c: _skip_epoch_outs(c, n, m, cfg))

    def epoch_step(state, k_b):
        carry, done_prev, n_run = state

        def live(_):
            carry2, outs = run_one(carry, k_b)
            # freeze finished problems: keep their old carry, emit the
            # same placeholder outputs the single-problem skip branch does
            def keep(old, new):
                d = done_prev.reshape((B,) + (1,) * (new.ndim - 1))
                return jnp.where(d, old, new)
            carry2 = jax.tree_util.tree_map(keep, carry, carry2)
            outs = jax.tree_util.tree_map(keep, skip_outs_b(carry), outs)
            return carry2, outs

        def skip(_):
            return carry, skip_outs_b(carry)

        carry2, outs = jax.lax.cond(jnp.all(done_prev), skip, live, None)
        found = jax.vmap(lambda o: epoch_found(o, cfg))(outs)
        done = done_prev | found
        n_run = n_run + (~done_prev).astype(jnp.int32)
        return (carry2, done, n_run), outs

    state0 = (carry0,
              jnp.zeros((B,), bool) if done0 is None else done0,
              jnp.zeros((B,), jnp.int32))
    (carry, _, epochs_run), outs = jax.lax.scan(epoch_step, state0, keys)
    return carry, outs, epochs_run


def _match_batch_body(keys: jax.Array, Qb: jax.Array, Gb: jax.Array,
                      maskb: jax.Array, cfg: PSOConfig, carry0):
    """Algorithm 1 vmapped over a leading problem axis B.

    ``keys`` is (B,) PRNG keys — one per problem, split per problem into
    epoch keys so problem b consumes exactly the key stream a sequential
    ``match(keys[b], ...)`` would.
    """
    B, n, m = maskb.shape
    bk = kernel_backend.for_config(cfg)
    if cfg.prune_mask:
        maskb, prune_sweeps = bk.prune_fixpoint_batch(maskb, Qb, Gb,
                                                      cfg.prune_iters)
    else:
        prune_sweeps = jnp.zeros((B,), jnp.int32)
    # (B, T) epoch keys -> (T, B) for the scan
    epoch_keys = jax.vmap(lambda k: jax.random.split(k, cfg.epochs))(keys)
    epoch_keys = jnp.swapaxes(epoch_keys, 0, 1)

    if cfg.early_exit and cfg.carry_fastpath:
        M_c, carry_ok = jax.vmap(
            lambda c, Q, G, mk: carry_fast_path(c, Q, G, mk, cfg)
        )(carry0, Qb, Gb, maskb)
    else:
        M_c = jnp.zeros((B, n, m), jnp.uint8)
        carry_ok = jnp.zeros((B,), bool)

    def run_one(carry, k_b):
        carry, outs = run_epoch_batch(carry, k_b, Qb, Gb, maskb, cfg)
        del outs["S_final"]  # only needed by the distributed consensus
        return carry, outs

    (S_star, f_star, S_bar), outs, epochs_run = scan_epochs_batch(
        run_one, carry0, epoch_keys, n, m, cfg, done0=carry_ok)
    outs["S_star"] = S_star
    outs["f_star"] = f_star
    outs["S_bar"] = S_bar
    outs["epochs_run"] = epochs_run
    outs["carry_mapping"] = M_c
    outs["carry_feasible"] = carry_ok
    outs["prune_sweeps"] = prune_sweeps
    return outs


# Module-level jitted entry point (cfg is static). The online
# ``MatcherService`` builds its *own* per-bucket jit wrappers around
# ``_match_body`` so cached executables have a bounded, evictable lifetime.
_match_impl = functools.partial(jax.jit, static_argnames=("cfg",))(_match_body)

_match_batch_impl = functools.partial(jax.jit, static_argnames=("cfg",))(
    _match_batch_body)


def match_batch(keys: jax.Array, Qb: jax.Array, Gb: jax.Array,
                maskb: jax.Array, cfg: PSOConfig, carry0=None):
    """Batched Algorithm 1: B problems solved in one dispatch.

    Inputs are stacked on a leading problem axis: ``keys`` (B,) PRNG keys,
    ``Qb`` (B, n, n), ``Gb`` (B, m, m), ``maskb`` (B, n, m); ``carry0``
    optionally warm-starts each problem with its own ``(S*, f*, S̄)``
    (stack per-problem carries; ``None`` is the cold prior for all).

    Returns the ``match`` output pytree with a problem axis after the
    epoch axis: mappings (T, B, N, n, m), feasible/fitness (T, B, N),
    f_star_trace (T, B, K), S_star (B, n, m), f_star (B,), S_bar
    (B, n, m), epochs_run (B,) — each problem's slice equals what an
    independent ``match(keys[b], ...)`` returns (per-problem early exit
    included).
    """
    if carry0 is None:
        carry0 = default_carry_batch(jnp.asarray(maskb))
    return _match_batch_impl(keys, Qb, Gb, maskb, cfg, carry0)


def match(key: jax.Array, Q: jax.Array, G: jax.Array, mask: jax.Array,
          cfg: PSOConfig, carry0=None):
    """Single-device Algorithm 1: T epochs × N particles.

    ``carry0`` optionally warm-starts the global controller state with a
    previous call's ``(S_star, f_star, S_bar)`` for the same problem
    (see ``MatchResult.carry`` / the online ``MatcherService``); ``None``
    is the cold uniform prior.

    Returns a dict with per-epoch stacked results:
      mappings  (T, N, n, m) uint8
      feasible  (T, N) bool
      fitness   (T, N) f32
      f_star_trace (T, K) f32   — global-best trajectory (Fig. 2b)
      S_star/f_star/S_bar       — final controller state (warm-start carry)
      epochs_run                — epochs actually executed (< T under
                                  ``cfg.early_exit``)
    """
    if carry0 is None:
        carry0 = default_carry(mask)
    return _match_impl(key, Q, G, mask, cfg, carry0)


def best_feasible(outs) -> Optional[jnp.ndarray]:
    """Highest-fitness feasible mapping of an epoch trace, or None.

    The select runs on device: the feasibility flags and fitness values
    stay resident, the winning row is picked with one masked argmax, and
    only an any-feasible scalar plus that single (n, m) mapping cross to
    the host — not the full (T·N, n, m) trace a per-leaf ``np.asarray``
    used to move.
    """
    import numpy as np
    feas = jnp.ravel(jnp.asarray(outs["feasible"]))
    fit = jnp.ravel(jnp.asarray(outs["fitness"]))
    maps = jnp.asarray(outs["mappings"])
    maps = maps.reshape(-1, maps.shape[-2], maps.shape[-1])
    # feasible entries rank by their own fitness (-inf fits clamped to
    # the finite minimum so they still outrank every infeasible slot)
    fmin = jnp.finfo(jnp.float32).min
    score = jnp.where(feas,
                      jnp.nan_to_num(fit.astype(jnp.float32),
                                     neginf=fmin, posinf=jnp.finfo(
                                         jnp.float32).max),
                      -jnp.inf)
    idx = jnp.argmax(score)
    any_feasible, best = jax.device_get((feas.any(), maps[idx]))
    if not bool(any_feasible):
        return None
    return np.asarray(best)
