"""Sharded, atomic, async checkpointing with elastic restore.

Layout (one directory per step)::

    <dir>/step_000123.tmp/            # written here first
        META.json                     # tree structure, shapes, dtypes, step
        <leaf-path>.npy               # one file per leaf (process-local)
        extras.json                   # data cursor, rng, user metadata
    <dir>/step_000123/                # atomic rename on commit

Fault-tolerance properties:
  * **atomic commit** — a crash mid-write leaves only ``*.tmp`` dirs, which
    restore ignores; the newest committed step always wins;
  * **async** — ``save()`` snapshots device arrays to host then hands the
    file I/O to a writer thread (training resumes immediately);
  * **elastic restore** — arrays are saved with their *global* shape and
    re-laid-out via ``jax.make_array_from_callback`` against whatever mesh/
    sharding the restoring job provides (different device counts are fine);
  * multi-host: each process writes only leaves it owns
    (``process_index`` prefix); restore reads all prefixes. On a single
    process that degenerates to full arrays, which is what runs here.
"""
from __future__ import annotations

import json
import os
import re
import shutil
import threading
from typing import Any, Dict, Optional

import jax
import numpy as np
from jax.tree_util import tree_flatten_with_path

from repro.runtime.sharding import _path_names  # shared path naming


def _leaf_file(path_names) -> str:
    return "__".join(path_names) + ".npy"


class CheckpointManager:
    """Atomic, optionally-async checkpoint store rooted at ``directory``.

    Used for two state families: training state (arbitrary pytrees, via
    ``save``/``restore`` with a matching ``state_like``) and the matcher
    service's warm-restart snapshots (flat ``{name: array}`` dicts, via
    ``save``/``restore_flat`` — no template needed because the committed
    ``META.json`` fully describes a flat dict). ``keep`` bounds the
    number of committed steps retained on disk (oldest GC'd first).
    """

    def __init__(self, directory: str, async_save: bool = True,
                 keep: int = 3):
        self.dir = directory
        self.async_save = async_save
        self.keep = keep
        self._thread: Optional[threading.Thread] = None
        os.makedirs(directory, exist_ok=True)

    # ------------------------------------------------------------------
    def save(self, step: int, state: Any,
             extras: Optional[Dict] = None) -> None:
        """Commit ``state`` (any pytree of arrays) as step ``step``.

        Arrays are snapshotted to host memory synchronously; file I/O
        runs on a writer thread when ``async_save`` (call ``wait()`` to
        join it). ``extras`` must be JSON-serializable — snapshot
        metadata (format version, config digest, store keys) rides here.
        The commit is atomic: a crash mid-write leaves only a ``*.tmp``
        directory, which every restore path ignores."""
        self.wait()                      # one in-flight save at a time
        flat, treedef = tree_flatten_with_path(state)
        # snapshot to host memory synchronously (cheap vs file I/O)
        host = [(_path_names(p), np.asarray(jax.device_get(v)))
                for p, v in flat]
        meta = {
            "step": int(step),
            "leaves": [{"file": _leaf_file(p), "path": list(p),
                        "shape": list(v.shape), "dtype": str(v.dtype)}
                       for p, v in host],
        }

        def write():
            tmp = os.path.join(self.dir, f"step_{step:09d}.tmp")
            final = os.path.join(self.dir, f"step_{step:09d}")
            os.makedirs(tmp, exist_ok=True)
            for p, v in host:
                np.save(os.path.join(tmp, _leaf_file(p)), v)
            with open(os.path.join(tmp, "META.json"), "w") as f:
                json.dump(meta, f)
            with open(os.path.join(tmp, "extras.json"), "w") as f:
                json.dump(extras or {}, f)
            if os.path.exists(final):
                shutil.rmtree(final)
            os.rename(tmp, final)        # atomic commit
            self._gc()

        if self.async_save:
            self._thread = threading.Thread(target=write, daemon=True)
            self._thread.start()
        else:
            write()

    def wait(self) -> None:
        """Join the in-flight async save, if any (idempotent)."""
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _gc(self) -> None:
        steps = self.all_steps()
        for s in steps[:-self.keep]:
            shutil.rmtree(os.path.join(self.dir, f"step_{s:09d}"),
                          ignore_errors=True)

    # ------------------------------------------------------------------
    def all_steps(self):
        """Sorted step numbers of every *committed* checkpoint (``*.tmp``
        partial writes are invisible here, which is what makes the
        rename-commit crash-safe)."""
        out = []
        for name in os.listdir(self.dir):
            m = re.fullmatch(r"step_(\d+)", name)
            if m:
                out.append(int(m.group(1)))
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        """Newest committed step, or None when the store is empty."""
        steps = self.all_steps()
        return steps[-1] if steps else None

    def restore_flat(self, step: Optional[int] = None):
        """Restore a checkpoint saved from a FLAT ``{name: array}`` dict.

        Unlike :meth:`restore` this needs no ``state_like`` template —
        the committed ``META.json`` lists every leaf's path and file, and
        a flat dict's tree structure is exactly that list. Returns
        ``(arrays, extras)`` with ``arrays`` a ``{name: np.ndarray}``
        dict, or ``(None, None)`` when no committed step exists (so
        callers can treat an empty store as a clean cold start rather
        than an error). Raises on non-flat checkpoints (nested paths)."""
        if step is None:
            step = self.latest_step()
        if step is None:
            return None, None
        d = os.path.join(self.dir, f"step_{step:09d}")
        with open(os.path.join(d, "META.json")) as f:
            meta = json.load(f)
        arrays: Dict[str, np.ndarray] = {}
        for leaf in meta["leaves"]:
            path = leaf["path"]
            if len(path) != 1:
                raise ValueError(
                    f"restore_flat on a nested checkpoint (leaf {path}); "
                    f"use restore(state_like) for pytree state")
            arrays[path[0]] = np.load(os.path.join(d, leaf["file"]))
        with open(os.path.join(d, "extras.json")) as f:
            extras = json.load(f)
        return arrays, extras

    def restore(self, state_like: Any, step: Optional[int] = None,
                shardings: Any = None):
        """Restore into the structure of ``state_like``. ``shardings`` (a
        matching pytree of jax.sharding.Sharding) re-lays-out each array
        for the *current* mesh — elastic across device counts."""
        if step is None:
            step = self.latest_step()
        assert step is not None, "no committed checkpoint found"
        d = os.path.join(self.dir, f"step_{step:09d}")
        flat, treedef = tree_flatten_with_path(state_like)
        shard_flat = None
        if shardings is not None:
            shard_flat = jax.tree.flatten(shardings)[0]
        out = []
        for i, (p, v) in enumerate(flat):
            arr = np.load(os.path.join(d, _leaf_file(_path_names(p))))
            arr = arr.astype(v.dtype) if hasattr(v, "dtype") else arr
            if shard_flat is not None:
                sh = shard_flat[i]
                arr = jax.make_array_from_callback(
                    arr.shape, sh, lambda idx, a=arr: a[idx])
            out.append(arr)
        state = jax.tree.unflatten(treedef, out)
        with open(os.path.join(d, "extras.json")) as f:
            extras = json.load(f)
        return state, extras
