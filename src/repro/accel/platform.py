"""Accelerator platform models (paper Table 2).

Edge:  64 engines × (128×128 MACs) @ 700 MHz
Cloud: 128 engines × (128×128 MACs) @ 700 MHz

Engines sit on a 2-D mesh NoC (8×8 / 8×16) with on-chip links — the TSS
substrate. A host CPU model is included because the LTS/IsoSched baselines
run their scheduling there.
"""
from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class Platform:
    name: str
    engines: int                 # number of engines (target-graph vertices)
    noc_rows: int
    noc_cols: int
    macs_per_engine: int         # systolic array size
    clock_hz: float
    sram_bytes_per_engine: int   # local tile buffer
    dram_bw_bytes: float         # off-chip bandwidth (shared)
    noc_link_bw_bytes: float     # per on-chip link
    # host CPU running serial schedulers (baselines)
    cpu_gops: float              # effective scalar-ish throughput
    cpu_dispatch_overhead_s: float

    @property
    def peak_macs_per_s(self) -> float:
        return self.engines * self.macs_per_engine * self.clock_hz

    def engine_tile_capacity_macs(self, tile_cycles: int = 4096) -> float:
        """MACs one engine retires in a scheduling tile quantum."""
        return self.macs_per_engine * tile_cycles


EDGE = Platform(
    name="edge", engines=64, noc_rows=8, noc_cols=8,
    macs_per_engine=128 * 128, clock_hz=700e6,
    sram_bytes_per_engine=256 * 1024,
    dram_bw_bytes=12.8e9, noc_link_bw_bytes=11.2e9,   # 128b @ 700MHz
    cpu_gops=8.0, cpu_dispatch_overhead_s=2e-6)

CLOUD = Platform(
    name="cloud", engines=128, noc_rows=8, noc_cols=16,
    macs_per_engine=128 * 128, clock_hz=700e6,
    sram_bytes_per_engine=512 * 1024,
    dram_bw_bytes=25.6e9, noc_link_bw_bytes=11.2e9,
    cpu_gops=16.0, cpu_dispatch_overhead_s=2e-6)

_PLATFORMS = {"edge": EDGE, "cloud": CLOUD}


def get_platform(name: str) -> Platform:
    return _PLATFORMS[name]
