"""End-to-end training driver.

    PYTHONPATH=src python -m repro.launch.train --arch qwen1.5-0.5b \
        --steps 300 --reduced --checkpoint-dir /tmp/ckpt

``--reduced`` shrinks the architecture (same family/topology) so a ~100M
model trains a few hundred steps on CPU; the full configs target the
production mesh. Features exercised: deterministic resumable data
pipeline, AdamW/Adafactor, grad accumulation, checkpoint/restart (resume
from the latest checkpoint automatically), straggler watchdog.
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import CheckpointManager
from repro.configs import get_config, get_train_config
from repro.data import DataPipeline, SyntheticLMDataset
from repro.models import build_model
from repro.runtime.ft import StepWatchdog
from repro.runtime.train_loop import make_train_state, make_train_step


def reduced_config(cfg, d_model: int = 512, layers: int = 8):
    """~100M-class variant of the same family (see tests for the tiny one)."""
    kw = dict(num_layers=layers, d_model=d_model,
              num_heads=max(4, d_model // 128), kv_heads=4,
              d_ff=d_model * 3, vocab_size=32000,
              compute_dtype="float32", param_dtype="float32")
    if cfg.family == "ssm":
        kw["num_layers"] = (layers // cfg.ssm.slstm_period + 1) \
            * cfg.ssm.slstm_period
        kw["kv_heads"] = kw["num_heads"]
    if cfg.family == "hybrid":
        kw["kv_heads"] = kw["num_heads"]
    if cfg.moe is not None:
        from repro.configs.base import MoEConfig
        kw["moe"] = MoEConfig(num_experts=8, top_k=2,
                              expert_d_ff=d_model,
                              shared_experts=min(cfg.moe.shared_experts, 1),
                              dense_residual_d_ff=d_model
                              if cfg.moe.dense_residual_d_ff else 0)
    if cfg.mla is not None:
        from repro.configs.base import MLAConfig
        kw["mla"] = MLAConfig(kv_lora_rank=128, q_lora_rank=192,
                              rope_head_dim=32, nope_head_dim=64,
                              v_head_dim=64)
    if cfg.mrope:
        hd = d_model // kw["num_heads"]
        kw["mrope_sections"] = (hd // 4, hd // 8, hd // 8)
    if cfg.family in ("encdec", "audio"):
        kw["encoder_layers"] = layers
    return cfg.replace(**kw)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen1.5-0.5b")
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--d-model", type=int, default=512)
    ap.add_argument("--layers", type=int, default=8)
    ap.add_argument("--checkpoint-dir", default="")
    ap.add_argument("--checkpoint-every", type=int, default=100)
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = reduced_config(cfg, args.d_model, args.layers)
    tcfg = get_train_config(args.arch)
    tcfg = type(tcfg)(**{**tcfg.__dict__, "microbatches": 1,
                         "total_steps": args.steps,
                         "warmup_steps": max(args.steps // 20, 5)})

    model = build_model(cfg)
    train_step = jax.jit(make_train_step(model, tcfg, mesh=None),
                         donate_argnums=(0,))

    dataset = SyntheticLMDataset(vocab_size=cfg.vocab_size,
                                 seq_len=args.seq, seed=args.seed)
    pipeline = DataPipeline(dataset, global_batch=args.batch)

    state = make_train_state(model, tcfg, jax.random.PRNGKey(args.seed))
    n_params = model.num_params(state["params"])
    print(f"arch={cfg.name} params={n_params/1e6:.1f}M "
          f"steps={args.steps} batch={args.batch}x{args.seq}")

    ckpt = None
    start_step = 0
    if args.checkpoint_dir:
        ckpt = CheckpointManager(args.checkpoint_dir)
        if ckpt.latest_step() is not None:
            state, extras = ckpt.restore(state)
            start_step = int(extras["step"])
            pipeline.load_state_dict(extras["pipeline"])
            print(f"resumed from step {start_step}")

    watchdog = StepWatchdog()
    t_start = time.time()
    for step in range(start_step, args.steps):
        batch = {k: jnp.asarray(v) for k, v in pipeline.next().items()}
        t0 = time.time()
        state, metrics = train_step(state, batch)
        loss = float(metrics["loss"])
        dt = time.time() - t0
        if watchdog.observe(dt):
            print(f"[watchdog] step {step} straggled: {dt * 1e3:.0f} ms")
        if step % args.log_every == 0 or step == args.steps - 1:
            print(f"step {step:5d} loss {loss:8.4f} "
                  f"gnorm {float(metrics['grad_norm']):7.3f} "
                  f"lr {float(metrics['lr']):.2e} {dt * 1e3:6.0f} ms")
        if not np.isfinite(loss):
            print("NaN loss — aborting")
            return 1
        if ckpt and (step + 1) % args.checkpoint_every == 0:
            ckpt.save(step + 1, state,
                      extras={"step": step + 1,
                              "pipeline": pipeline.state_dict()})
    if ckpt:
        ckpt.save(args.steps, state,
                  extras={"step": args.steps,
                          "pipeline": pipeline.state_dict()})
        ckpt.wait()
    print(f"done in {time.time() - t_start:.1f}s")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
