"""Sharding rules: param/batch/cache PartitionSpecs for any mesh.

Strategy (MaxText-style 2-D/3-D sharding):
  * **fsdp** = ("pod", "data") when the pod axis exists, else ("data",):
    parameters, gradients and optimizer state shard their *d_model-like*
    dimension here (ZeRO-3), activations shard batch here;
  * **tensor** = "model": head/ffn/expert/vocab dimensions shard here
    (Megatron-style), contracting through psum/reduce-scatter;
  * any dimension not divisible by its axis size falls back to replication
    (e.g. kv_heads=8 on a 16-way tensor axis → shard head_dim instead).

Rules are keyed by parameter *leaf name* with symbols per trailing dim:
  D → fsdp, V/F/H/E → tensor, h/None → replicated. Leading (stacked-layer)
  dims are always None. Optimizer-state leaves (m/v/vr/vc) inherit the
  parent parameter's rule.
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P
from jax.tree_util import DictKey, SequenceKey, tree_flatten_with_path


def get_shard_map():
    """Version-compat accessor for ``shard_map``.

    Returns a callable ``shard_map(f, mesh=..., in_specs=..., out_specs=...)``
    with replication checking disabled, across JAX versions:
      * newer JAX exposes ``jax.shard_map`` (``check_vma=`` kwarg),
      * 0.4.x only has ``jax.experimental.shard_map.shard_map``
        (``check_rep=`` kwarg).

    Every shard_map call site in this repo (and in test subprocess
    snippets) must go through here rather than touching ``jax.shard_map``
    directly.
    """
    sm = getattr(jax, "shard_map", None)
    if sm is None:
        from jax.experimental.shard_map import shard_map as sm  # noqa: N813
        kwarg_prefs = ({"check_rep": False}, {"check_vma": False})
    else:
        kwarg_prefs = ({"check_vma": False}, {"check_rep": False})

    def wrap(f, *, mesh, in_specs, out_specs):
        # the check-disable kwarg was renamed across versions; try both
        # names before giving it up (the matcher's fused collectives rely
        # on the replication checker being off)
        for kw in kwarg_prefs:
            try:
                return sm(f, mesh=mesh, in_specs=in_specs,
                          out_specs=out_specs, **kw)
            except TypeError:
                continue
        return sm(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs)

    return wrap


def mesh_axes(mesh: Mesh, profile: str = "2d"):
    """profile "2d": fsdp over (pod, data) + tensor over "model".
    profile "fsdp_only": every axis joins the FSDP/batch group and tensor
    parallelism is disabled — the right shape for ≤10B-dense training,
    where TP's per-layer activation all-reduces dominate the collective
    roofline term (EXPERIMENTS.md §Perf, llama3-8b train hillclimb)."""
    names = mesh.axis_names
    if profile == "fsdp_only":
        return tuple(names), None
    fsdp = tuple(n for n in ("pod", "data") if n in names)
    tensor = "model" if "model" in names else None
    return fsdp, tensor


# symbol table: trailing-dim symbols per param leaf name
_RULES: Dict[str, Tuple] = {
    # embeddings / head
    "embed": ("V", "D"),
    "lm_head": ("D", "V"),
    "patch_proj": ("D", "F"),
    "frame_proj": ("D", "F"),
    # attention (GQA)
    "wq": ("D", "H", None),
    "wk": ("D", "H", None),
    "wv": ("D", "H", None),
    "wo": ("H", None, "D"),
    "bq": ("H", None),
    "bk": ("H", None),
    "bv": ("H", None),
    # attention (MLA)
    "wq_a": ("D", None),
    "wq_b": (None, "H", None),
    "wkv_a": ("D", None),
    "wk_rope": ("D", None),
    "wk_b": (None, "H", None),
    "wv_b": (None, "H", None),
    # mlp
    "gate": ("D", "F"),
    "up": ("D", "F"),
    "down": ("F", "D"),
    "router": ("D", None),
    # ssm / xlstm
    "in_proj": ("D", "F"),
    "out_proj": ("F", "D"),
    "up_proj": ("D", "F"),
    "down_proj": ("F", "D"),
    "conv_w": (None, "F"),
    "conv_b": ("F",),
    "wqkv": ("F", None, "H", None),
    "wif": ("F", None),
    "w_in": ("D", None, "H", None),
    "r": ("H", None, None, None),
    # scalars / vectors → replicated
    "scale": (None,),
    "A_log": (None,),
    "D": (None,),
    "dt_bias": (None,),
    "if_bias": (None,),
    "bias": (None, None, None),
}

# inside an "experts" subtree the leading expert dim shards on tensor and
# the ffn dim stays local (tensor axis already used by E)
_EXPERT_RULES = {
    "gate": ("E", "D", None),
    "up": ("E", "D", None),
    "down": ("E", None, "D"),
}

_SYMBOL_TO_AXIS = {"D": "fsdp", "V": "tensor", "F": "tensor", "H": "tensor",
                   "E": "tensor", None: None}


def _path_names(path) -> Tuple[str, ...]:
    out = []
    for k in path:
        if isinstance(k, DictKey):
            out.append(str(k.key))
        elif isinstance(k, SequenceKey):
            out.append(str(k.idx))
    return tuple(out)


def _axes_size(mesh: Mesh, axes) -> int:
    if axes is None:
        return 1
    if isinstance(axes, str):
        return mesh.shape[axes]
    return int(np.prod([mesh.shape[a] for a in axes]))


def _resolve(rule: Tuple, shape: Tuple[int, ...], mesh: Mesh,
             fsdp, tensor) -> P:
    """Trailing-dim rule → PartitionSpec with divisibility fallbacks."""
    ndim = len(shape)
    spec: list = [None] * ndim
    offset = ndim - len(rule)
    if offset < 0:           # rule longer than shape (e.g. squeezed bias)
        rule = rule[-ndim:]
        offset = 0
    used_tensor = False
    for i, sym in enumerate(rule):
        dim = offset + i
        kind = _SYMBOL_TO_AXIS.get(sym)
        if kind == "fsdp" and fsdp:
            if shape[dim] % _axes_size(mesh, fsdp) == 0:
                spec[dim] = fsdp if len(fsdp) > 1 else fsdp[0]
        elif kind == "tensor" and tensor and not used_tensor:
            if shape[dim] % _axes_size(mesh, tensor) == 0:
                spec[dim] = tensor
                used_tensor = True
    return P(*spec)


def spec_for_param(path_names: Tuple[str, ...], shape, mesh: Mesh,
                   profile: str = "2d") -> P:
    fsdp, tensor = mesh_axes(mesh, profile)
    names = [n for n in path_names if n not in ("m", "v", "f")]
    # optimizer-state leaves inherit the parent param rule
    leaf = names[-1] if names else ""
    if leaf in ("vr", "vc", "v", "error") and len(names) >= 2:
        parent = names[-2]
        rule = (_EXPERT_RULES.get(parent) if "experts" in names
                else None) or _RULES.get(parent)
        if rule is None:
            return P()
        if leaf == "vr":      # param minus last dim
            rule = rule[:-1]
        elif leaf == "vc":    # param minus second-to-last dim
            rule = rule[:-2] + rule[-1:]
        return _resolve(rule, shape, mesh, fsdp, tensor)
    if "experts" in names and leaf in _EXPERT_RULES:
        return _resolve(_EXPERT_RULES[leaf], shape, mesh, fsdp, tensor)
    rule = _RULES.get(leaf)
    if rule is None:
        return P()
    return _resolve(rule, shape, mesh, fsdp, tensor)


def infer_param_specs(params, mesh: Mesh, profile: str = "2d"):
    flat, treedef = tree_flatten_with_path(params)
    specs = [spec_for_param(_path_names(p), v.shape, mesh, profile)
             for p, v in flat]
    return jax.tree.unflatten(treedef, specs)


# ---------------------------------------------------------------------------
# Batch / cache specs
# ---------------------------------------------------------------------------

def spec_for_batch_leaf(name: str, shape, mesh: Mesh,
                        profile: str = "2d") -> P:
    fsdp, tensor = mesh_axes(mesh, profile)
    dp = fsdp if len(fsdp) > 1 else (fsdp[0] if fsdp else None)
    dp_size = _axes_size(mesh, fsdp)
    if name == "positions3":         # (3, B, S)
        if shape[1] % dp_size == 0:
            return P(None, dp, None)
        return P()
    spec: list = [None] * len(shape)
    if shape and shape[0] % dp_size == 0 and shape[0] > 1:
        spec[0] = dp
    elif len(shape) >= 2 and shape[1] % dp_size == 0 and shape[1] > 1:
        spec[1] = dp                 # batch=1 → shard sequence (CP)
    return P(*spec)


def infer_batch_specs(batch, mesh: Mesh, profile: str = "2d"):
    flat, treedef = tree_flatten_with_path(batch)
    specs = [spec_for_batch_leaf(_path_names(p)[-1], v.shape, mesh, profile)
             for p, v in flat]
    return jax.tree.unflatten(treedef, specs)


_CACHE_HEAD_DIM = {"k": -2, "v": -2}


def spec_for_cache_leaf(name: str, shape, mesh: Mesh,
                        profile: str = "2d") -> P:
    """KV caches: (lead..., B, S, Hkv, Dh); states: (lead..., B, H, Dk, Dv);
    conv: (lead..., B, K, C); memory: (B, S, D); latents: (B, S, R)."""
    fsdp, tensor = mesh_axes(mesh, profile)
    dp = fsdp if len(fsdp) > 1 else (fsdp[0] if fsdp else None)
    dp_size = _axes_size(mesh, fsdp)
    t_size = _axes_size(mesh, tensor) if tensor else 1
    ndim = len(shape)
    spec: list = [None] * ndim

    if name in ("k", "v"):            # (..., B, S, Hkv, Dh)
        b_dim, s_dim, h_dim, d_dim = ndim - 4, ndim - 3, ndim - 2, ndim - 1
        if shape[b_dim] % dp_size == 0 and shape[b_dim] > 1:
            spec[b_dim] = dp
        elif shape[s_dim] % dp_size == 0:
            spec[s_dim] = dp          # context-parallel long decode
        if tensor:
            if shape[h_dim] % t_size == 0:
                spec[h_dim] = tensor
            elif spec[s_dim] is None and shape[s_dim] % t_size == 0:
                # kv_heads < tensor axis: shard the sequence instead
                # (flash-decode; matches _sdpa's decode constraints)
                spec[s_dim] = tensor
            elif shape[d_dim] % t_size == 0:
                spec[d_dim] = tensor
    elif name in ("ckv", "k_rope", "memory"):   # (..., B, S, R)
        b_dim, s_dim, r_dim = ndim - 3, ndim - 2, ndim - 1
        if shape[b_dim] % dp_size == 0 and shape[b_dim] > 1:
            spec[b_dim] = dp
        elif shape[s_dim] % dp_size == 0:
            spec[s_dim] = dp
        if tensor and name == "ckv" and shape[r_dim] % t_size == 0:
            spec[r_dim] = tensor
    elif name == "state":             # (..., B, H, Dk, Dv)
        b_dim, h_dim, k_dim = ndim - 4, ndim - 3, ndim - 2
        if shape[b_dim] % dp_size == 0 and shape[b_dim] > 1:
            spec[b_dim] = dp
        if tensor:
            if shape[h_dim] % t_size == 0:
                spec[h_dim] = tensor
            elif shape[k_dim] % t_size == 0:
                spec[k_dim] = tensor
    elif name == "conv":              # (..., B, K, C)
        b_dim, c_dim = ndim - 3, ndim - 1
        if shape[b_dim] % dp_size == 0 and shape[b_dim] > 1:
            spec[b_dim] = dp
        if tensor and shape[c_dim] % t_size == 0:
            spec[c_dim] = tensor
    elif name in ("c", "n", "h", "m"):  # slstm scalars (..., B, H, Dh)
        b_dim = ndim - 3
        if 0 <= b_dim and shape[b_dim] % dp_size == 0 and shape[b_dim] > 1:
            spec[b_dim] = dp
    return P(*spec)


def infer_cache_specs(caches, mesh: Mesh, profile: str = "2d"):
    flat, treedef = tree_flatten_with_path(caches)
    specs = [spec_for_cache_leaf(_path_names(p)[-1], v.shape, mesh, profile)
             for p, v in flat]
    return jax.tree.unflatten(treedef, specs)


def named(specs, mesh: Mesh):
    return jax.tree.map(lambda s: NamedSharding(mesh, s), specs,
                        is_leaf=lambda x: isinstance(x, P))


def logits_spec(mesh: Mesh, profile: str = "2d") -> P:
    fsdp, tensor = mesh_axes(mesh, profile)
    dp = fsdp if len(fsdp) > 1 else (fsdp[0] if fsdp else None)
    return P(dp, None, tensor)
