"""Feed-forward blocks: SwiGLU MLP (+ the dense residual used by Arctic)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import common
from repro.models.common import dense_init


def init_mlp(key, d_model: int, d_ff: int, dtype) -> dict:
    ks = jax.random.split(key, 3)
    return {
        "gate": dense_init(ks[0], (d_model, d_ff), dtype),
        "up": dense_init(ks[1], (d_model, d_ff), dtype),
        "down": dense_init(ks[2], (d_ff, d_model), dtype),
    }


def mlp(params: dict, x: jax.Array, compute_dtype) -> jax.Array:
    h = jnp.einsum("bsd,df->bsf", x.astype(compute_dtype),
                   params["gate"].astype(compute_dtype))
    u = jnp.einsum("bsd,df->bsf", x.astype(compute_dtype),
                   params["up"].astype(compute_dtype))
    h = jax.nn.silu(h) * u
    out = jnp.einsum("bsf,fd->bsd", h, params["down"].astype(compute_dtype))
    return out.astype(x.dtype)
