"""xLSTM-1.3B [arXiv:2405.04517]: mLSTM blocks with periodic sLSTM
(7:1 ratio). Sub-quadratic -> runs long_500k."""
from repro.configs.base import ModelConfig, SSMConfig

CONFIG = ModelConfig(
    name="xlstm-1.3b", family="ssm", num_layers=48, d_model=2048,
    num_heads=4, kv_heads=4, d_ff=0, vocab_size=50304,
    ssm=SSMConfig(kind="xlstm", expand=2, conv_dim=4, chunk=256,
                  slstm_period=8),
    sub_quadratic=True)
