"""Benchmark harness: one function per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows. Paper-band comparisons are
summarized at the end (see EXPERIMENTS.md for interpretation).

Usage: PYTHONPATH=src python -m benchmarks.run [--only fig6,fig7,...]
"""
from __future__ import annotations

import argparse
import sys


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default="",
                    help="comma list: fig2a,fig2b,fig6,fig7,fig8,quant,"
                         "matcher,batch")
    args = ap.parse_args()

    from benchmarks import figures

    benches = {
        "fig2a": figures.fig2a_sched_overhead,
        "fig2b": figures.fig2b_relaxation,
        "fig6": figures.fig6_speedup,
        "fig7": figures.fig7_lbt,
        "fig8": figures.fig8_energy,
        "quant": figures.quant_ablation,
        "matcher": figures.matcher_scaling,
        "batch": figures.fig_batch,
    }
    selected = (args.only.split(",") if args.only else list(benches))

    print("name,us_per_call,derived")
    for name in selected:
        rows = benches[name.strip()]()
        for rname, us, derived in rows:
            print(f"{rname},{us:.1f},{derived}")
        sys.stdout.flush()


if __name__ == "__main__":
    main()
