"""Fault tolerance via the paper's own mechanism: when engines fail
mid-run, drop them from the target graph G and re-run the subgraph matcher
to remap the workload onto the surviving engine DAG.

    PYTHONPATH=src python examples/fault_tolerant_rematch.py
"""
import numpy as np

from repro.accel import EDGE
from repro.runtime.ft import remap_on_failure, elastic_mesh_shape
from repro.workloads import get_workload


def main():
    wl = get_workload("resnet50")

    print("healthy array:")
    mapping, target = remap_on_failure(EDGE, wl, failed_engines=[])
    assert mapping is not None
    print(f"  mapped {mapping.shape[0]} tiles onto {target.n} engines")

    # fail a whole NoC row (engines 0..7) plus two more
    failed = list(range(8)) + [21, 42]
    print(f"after failing engines {failed}:")
    mapping, target = remap_on_failure(EDGE, wl, failed_engines=failed)
    assert mapping is not None, "re-match failed"
    engine_ids = target.weights.astype(int)
    used = sorted(int(engine_ids[j]) for j in np.where(mapping)[1])
    assert not (set(used) & set(failed)), "mapped onto a failed engine!"
    print(f"  re-mapped {mapping.shape[0]} tiles onto "
          f"{target.n} surviving engines; none failed: OK")

    # the pod-level analogue: elastic mesh rebuild after losing hosts
    for n in (512, 496, 256, 240):
        shape, axes = elastic_mesh_shape(n)
        print(f"  {n} live devices -> mesh {shape} {axes}")


if __name__ == "__main__":
    main()
