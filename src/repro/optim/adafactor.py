"""Adafactor: factored second moments for ≥2-D params (O(n+m) state
instead of O(n·m)). The giant-arch optimizer (qwen1.5-110b, deepseek-v2,
arctic): optimizer HBM shrinks from 2×params to ~per-row/col vectors.
No first moment (classic Adafactor-without-momentum)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.common import DTYPES
from repro.optim.adamw import Optimizer


def adafactor(decay: float = 0.8, eps: float = 1e-30,
              clip_threshold: float = 1.0, weight_decay: float = 0.0,
              state_dtype: str = "float32") -> Optimizer:
    sdt = DTYPES[state_dtype]

    def _factored(p):
        return p.ndim >= 2

    def init(params):
        def one(p):
            if _factored(p):
                return {"vr": jnp.zeros(p.shape[:-1], sdt),
                        "vc": jnp.zeros(p.shape[:-2] + p.shape[-1:], sdt)}
            return {"v": jnp.zeros(p.shape, sdt)}
        return {"f": jax.tree.map(one, params,
                                  is_leaf=lambda x: hasattr(x, "shape")),
                "count": jnp.zeros((), jnp.int32)}

    def update(grads, state, params, lr):
        count = state["count"] + 1
        beta = 1.0 - count.astype(jnp.float32) ** -decay

        def upd(g, st, p):
            g = g.astype(jnp.float32)
            g2 = g * g + eps
            if _factored(p):
                vr = beta * st["vr"].astype(jnp.float32) + \
                    (1 - beta) * g2.mean(axis=-1)
                vc = beta * st["vc"].astype(jnp.float32) + \
                    (1 - beta) * g2.mean(axis=-2)
                denom = (vr[..., None] * vc[..., None, :]
                         / jnp.maximum(vr.mean(-1)[..., None, None], eps))
                step = g * jax.lax.rsqrt(denom + eps)
                new_st = {"vr": vr.astype(sdt), "vc": vc.astype(sdt)}
            else:
                v = beta * st["v"].astype(jnp.float32) + (1 - beta) * g2
                step = g * jax.lax.rsqrt(v + eps)
                new_st = {"v": v.astype(sdt)}
            # relative step clipping (RMS-based)
            rms = jnp.sqrt(jnp.mean(step * step) + eps)
            step = step / jnp.maximum(1.0, rms / clip_threshold)
            if weight_decay and p.ndim >= 2:
                step = step + weight_decay * p.astype(jnp.float32)
            new_p = p.astype(jnp.float32) - lr * step
            return new_p.astype(p.dtype), new_st

        leaves_is = lambda x: hasattr(x, "shape")
        out = jax.tree.map(upd, grads, state["f"], params, is_leaf=None)
        new_params = jax.tree.map(lambda t: t[0], out,
                                  is_leaf=lambda t: isinstance(t, tuple))
        new_f = jax.tree.map(lambda t: t[1], out,
                             is_leaf=lambda t: isinstance(t, tuple))
        return new_params, {"f": new_f, "count": count}

    return Optimizer(init=init, update=update)
