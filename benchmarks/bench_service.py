"""Online matcher-service benchmark: cold vs warm arrival latency.

Measures what the service layer buys on the scheduling hot path:

  * **cold first call** — new shape bucket: jit compile + cold swarm,
  * **warm repeats** — same bucket + warm-start carry: executable reuse,
    previous consensus S̄/S* as the prior, early-exit epochs,
  * **warm-start epochs** — epochs to a feasible mapping, warm vs cold,
    on the planted-match pair.

Emits ``BENCH_service.json`` and CSV rows on stdout.

Usage: PYTHONPATH=src python -m benchmarks.bench_service [--repeats N]
"""
from __future__ import annotations

import argparse
import json
import statistics
import time

import jax

from repro.core import graphs, pso
from repro.core.service import MatcherService


def _planted(seed: int, n: int, m: int):
    key = jax.random.PRNGKey(seed)
    kq, kt = jax.random.split(key)
    q = graphs.random_dag(kq, n, 0.35)
    g = graphs.embed_query_in_target(kt, q, m)
    return q, g


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--repeats", type=int, default=20,
                    help="warm repeat calls (min 1)")
    ap.add_argument("--out", default="BENCH_service.json")
    args = ap.parse_args()
    args.repeats = max(args.repeats, 1)

    cfg = pso.PSOConfig(num_particles=48, epochs=6, inner_steps=10)
    svc = MatcherService(cfg)
    q, g = _planted(2, 10, 24)
    key = jax.random.PRNGKey(0)

    # ---- cold first call: compile + cold swarm --------------------------
    t0 = time.perf_counter()
    cold = svc.match(q, g, key=key, workload_key="bench")
    cold_s = time.perf_counter() - t0
    assert cold.found, "planted pair must match"
    cold_epochs = cold.epochs_run

    # ---- warm repeats: same shape bucket, warm-start carry --------------
    warm_lat = []
    warm_epochs = []
    for i in range(args.repeats):
        k = jax.random.PRNGKey(i + 1)
        t0 = time.perf_counter()
        r = svc.match(q, g, key=k, workload_key="bench")
        warm_lat.append(time.perf_counter() - t0)
        warm_epochs.append(r.epochs_run)
        assert r.compile_cache_hit and r.warm_hit and r.found

    warm_med = statistics.median(warm_lat)
    speedup = cold_s / max(warm_med, 1e-12)

    # ---- warm-start epoch comparison on a fresh service -----------------
    # (isolate the carry effect from the compile cache: both calls below
    # hit the compiled executable, only the prior differs)
    svc2 = MatcherService(cfg)
    svc2.match(q, g, key=jax.random.PRNGKey(100), workload_key="w")  # compile
    svc2.clear_carries()
    cold2 = svc2.match(q, g, key=jax.random.PRNGKey(101), workload_key="w")
    warm2 = svc2.match(q, g, key=jax.random.PRNGKey(102), workload_key="w")
    assert not cold2.warm_hit and warm2.warm_hit

    result = {
        "cold_first_call_s": cold_s,
        "warm_repeat_median_s": warm_med,
        "warm_repeat_p90_s": sorted(warm_lat)[int(0.9 * len(warm_lat))],
        "cold_vs_warm_speedup": speedup,
        "cold_epochs_to_feasible": int(cold_epochs),
        "warm_epochs_median": int(statistics.median(warm_epochs)),
        "warm_carry_epochs": int(warm2.epochs_run),
        "cold_carry_epochs": int(cold2.epochs_run),
        "epoch_budget": cfg.epochs,
        "stats": svc.stats_dict(),
    }
    with open(args.out, "w") as f:
        json.dump(result, f, indent=2)

    print("name,us_per_call,derived")
    print(f"service_cold_first,{cold_s * 1e6:.1f},compile+cold-swarm")
    print(f"service_warm_repeat,{warm_med * 1e6:.1f},"
          f"speedup=x{speedup:.1f}")
    print(f"service_warm_epochs,{warm2.epochs_run},"
          f"cold={cold2.epochs_run} budget={cfg.epochs}")
    ok = speedup >= 5.0 and warm2.epochs_run <= cold2.epochs_run
    print(f"service_acceptance,{0.0},{'PASS' if ok else 'FAIL'}")


if __name__ == "__main__":
    main()
