"""Distributed IMMSched matcher: particles sharded over the device mesh.

This is the paper's "particles → engines" mapping lifted to pod scale:
every device runs a local swarm (vmap), and the *global controller* of the
paper becomes a collective schedule executed once per epoch:

  * global best  S*, f*  — all_gather of per-device bests + argmax select
  * consensus    S̄      — psum of per-device elite-weighted sums (a global
                           softmax over the union of local elites, computed
                           with a pmax-stabilized exponent)

The collectives are O(n·m·D) bytes per epoch vs O(N·K·n·m²) FLOPs of local
work, so the matcher scales ~linearly in devices — the multi-pod dry-run
compiles exactly this program on the 2×16×16 mesh.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.core import pso
from repro.core.graphs import Graph, as_device_graphs
from repro.kernels import backend as kernel_backend
from repro.runtime.sharding import get_shard_map


@dataclasses.dataclass
class MatchResult:
    mapping: Optional[np.ndarray]        # best feasible (n, m) or None
    feasible_count: int
    f_star: float
    f_star_trace: np.ndarray             # (T, K) global-best trajectory
    all_mappings: np.ndarray             # (T*N, n, m) projected mappings
    all_feasible: np.ndarray             # (T*N,)
    all_fitness: np.ndarray              # (T*N,)
    carry: Optional[tuple] = None        # (S_star, f_star, S_bar) warm-start
    epochs_run: int = 0                  # epochs executed (< T on early exit)
    carry_verified: bool = False         # warm carry re-validated by one
                                         # projection (0-epoch fast path)
    prune_sweeps: int = 0                # fused pre-prune iterations run
                                         # (0 when prune_mask is off)

    @property
    def found(self) -> bool:
        return self.mapping is not None


def collect_result(outs, order=None, crop=None) -> MatchResult:
    """Host-side gather of a match-output pytree into a ``MatchResult``.

    ``order``: topological relabelling to undo (rows back to caller
    order). ``crop``: logical ``(n, m)`` to strip shape-bucket padding to
    before undoing the relabelling (used by the online service).

    Device output pytrees are fetched with ONE blocking ``device_get``
    up front (a single host sync for the whole result) instead of one
    implicit transfer per leaf; already-fetched host trees pass through
    untouched.
    """
    outs = jax.device_get(outs)
    feas = np.asarray(outs["feasible"]).reshape(-1)
    fit = np.asarray(outs["fitness"]).reshape(-1)
    maps = np.asarray(outs["mappings"])
    maps = maps.reshape(-1, maps.shape[-2], maps.shape[-1])
    if crop is not None:
        n, m = crop
        maps = maps[:, :n, :m]
    if order is not None:
        unperm = np.empty_like(maps)
        unperm[:, order, :] = maps
        maps = unperm
    best = None
    if feas.any():
        idx = np.where(feas)[0]
        best = maps[idx[np.argmax(fit[idx])]]
    carry_ok = bool(np.asarray(
        outs.get("carry_feasible", False)).reshape(-1)[-1])
    if best is None and carry_ok:
        # warm-carry fast path: every epoch was skipped, the re-validated
        # projection of the carried S* IS the mapping
        M_c = np.asarray(outs["carry_mapping"])
        M_c = M_c.reshape(-1, M_c.shape[-2], M_c.shape[-1])[-1]
        if crop is not None:
            M_c = M_c[:crop[0], :crop[1]]
        if order is not None:
            unperm = np.empty_like(M_c)
            unperm[order, :] = M_c
            M_c = unperm
        best = M_c
    return MatchResult(
        mapping=best,
        feasible_count=int(feas.sum()),
        f_star=float(np.asarray(outs["f_star"]).reshape(-1)[-1]),
        f_star_trace=np.asarray(outs["f_star_trace"]),
        all_mappings=maps, all_feasible=feas, all_fitness=fit,
        carry=(outs["S_star"], outs["f_star"], outs["S_bar"]),
        epochs_run=int(np.asarray(outs["epochs_run"]).reshape(-1)[-1]),
        carry_verified=carry_ok,
        prune_sweeps=int(np.asarray(outs.get("prune_sweeps", 0)
                                    ).reshape(-1)[-1]))


def split_batch_outs(outs, batch: int):
    """Split a ``match_batch`` output pytree into per-problem pytrees.

    The batch axis sits *after* the epoch axis on per-epoch leaves
    (mappings/feasible/fitness/f_star_trace are (T, B, ...)) and leads on
    the controller leaves (S_star/f_star/S_bar/epochs_run are (B, ...)).
    Each returned slice is exactly the pytree a single ``match`` call
    would produce, so it feeds straight into ``collect_result``.
    """
    per_epoch = {"mappings", "feasible", "fitness", "f_star_trace"}
    host = jax.device_get(dict(outs))   # ONE sync for the whole pytree
    return [{k: (v[:, b] if k in per_epoch else v[b])
             for k, v in host.items()}
            for b in range(batch)]


def collect_batch_results(outs, batch: int, orders=None, crops=None):
    """Host-side gather of batched match outputs into per-problem
    ``MatchResult``s (``orders``/``crops``: per-problem, or None)."""
    results = []
    for b, slice_b in enumerate(split_batch_outs(outs, batch)):
        results.append(collect_result(
            slice_b,
            order=None if orders is None else orders[b],
            crop=None if crops is None else crops[b]))
    return results


def _fuse_global_best(S_star, f_star, axis_names):
    """Select the global-best particle without gathering every device's S.

    v1 all-gathered (D, n, m) — D×65 KB per device per epoch. v2 (§Perf):
    pmax the scalar fitness, then a *masked psum* ships only the winner's
    S (ties averaged — they have equal fitness), cutting the collective
    bytes by ~D/2×.
    """
    f_gmax = jax.lax.pmax(f_star, axis_names)
    is_best = (f_star >= f_gmax).astype(S_star.dtype)
    count = jax.lax.psum(is_best, axis_names)
    S_best = jax.lax.psum(S_star * is_best, axis_names) \
        / jnp.maximum(count, 1.0)
    return S_best, f_gmax


def _fuse_consensus(S, f, cfg, axis_names):
    """Global elite consensus across devices (paper's global controller)."""
    f_gmax = jax.lax.pmax(jnp.max(f), axis_names)
    k = max(1, int(round(cfg.elite_frac * S.shape[0])))
    f_top, idx = jax.lax.top_k(f, k)
    w = jnp.exp((f_top - f_gmax) / cfg.consensus_temp)
    weighted = jnp.einsum("k,knm->nm", w, S[idx])
    wsum = jnp.sum(w)
    weighted = jax.lax.psum(weighted, axis_names)
    wsum = jax.lax.psum(wsum, axis_names)
    return weighted / jnp.maximum(wsum, 1e-20)


def build_distributed_match(Q_shape: Tuple[int, int], mesh: Mesh,
                            cfg: pso.PSOConfig,
                            axis_names: Sequence[str] = ("data",)):
    """Returns a jit'd ``match(keys, Q, G, mask, carry0)`` running the full
    Algorithm 1 with the swarm sharded over ``axis_names`` of ``mesh``.

    ``keys`` must be (num_shards,) PRNG keys (one per device slice);
    ``carry0`` is a replicated ``(S_star, f_star, S_bar)`` warm-start (use
    ``pso.default_carry(mask)`` for a cold start). The result pytree
    mirrors ``pso.match`` with a leading shard axis on the per-particle
    outputs.

    The returned executable is tagged ``aot_exportable = False``: a
    ``jax.export``-serialized shard_map program pins the exporting
    process's device topology, so the service's on-disk AOT cache must
    not persist it (a restart on a different mesh would fail or skew the
    collective schedule). Mesh executables lean on JAX's persistent XLA
    compilation cache instead (see ``core/persist.py``).
    """
    axis_names = tuple(axis_names)

    def local_match(key, Q, G, mask, carry0):
        n, m = mask.shape
        if cfg.prune_mask:
            mask, prune_sweeps = kernel_backend.for_config(
                cfg).prune_fixpoint(mask, Q, G, cfg.prune_iters)
        else:
            prune_sweeps = jnp.int32(0)
        keys = jax.random.split(key[0], cfg.epochs)  # this shard's key

        if cfg.early_exit and cfg.carry_fastpath:
            # carry0/Q/G/mask are replicated, so every shard computes the
            # same verdict — the early-exit branch stays collective-safe
            M_c, carry_ok = pso.carry_fast_path(carry0, Q, G, mask, cfg)
        else:
            M_c = jnp.zeros((n, m), jnp.uint8)
            carry_ok = jnp.bool_(False)

        def run_one(carry, k):
            carry, outs = pso.run_epoch(carry, k, Q, G, mask, cfg)
            S_star, f_star, _ = carry
            # ---- global controller: fuse across the mesh ----
            S_star, f_star = _fuse_global_best(S_star, f_star, axis_names)
            S_bar = _fuse_consensus(outs.pop("S_final"), outs["fitness"],
                                    cfg, axis_names)
            # global best-so-far trajectory (replicated)
            outs["f_star_trace"] = jax.lax.pmax(outs["f_star_trace"],
                                                axis_names)
            return (S_star, f_star, S_bar), outs

        def all_found(found):
            # replicate the early-exit predicate so every shard takes the
            # same lax.cond branch (the live branch holds collectives)
            return jax.lax.pmax(found.astype(jnp.int32), axis_names) > 0

        (S_star, f_star, S_bar), outs, epochs_run = pso.scan_epochs(
            run_one, carry0, keys, n, m, cfg, all_found=all_found,
            done0=carry_ok)
        outs["S_star"] = S_star
        outs["f_star"] = f_star
        outs["S_bar"] = S_bar
        outs["epochs_run"] = epochs_run
        outs["carry_mapping"] = M_c
        outs["carry_feasible"] = carry_ok
        outs["prune_sweeps"] = prune_sweeps
        return outs

    shard_axes = P(axis_names)
    in_specs = (shard_axes, P(), P(), P(), (P(), P(), P()))
    out_specs = dict(
        mappings=P(None, axis_names), feasible=P(None, axis_names),
        fitness=P(None, axis_names), f_star_trace=P(),
        S_star=P(), f_star=P(), S_bar=P(), epochs_run=P(),
        carry_mapping=P(), carry_feasible=P(), prune_sweeps=P())

    shard_map = get_shard_map()
    fn = shard_map(local_match, mesh=mesh, in_specs=in_specs,
                   out_specs=out_specs)
    return _mark_mesh_executable(jax.jit(fn))


def _mark_mesh_executable(fn):
    """Tag a mesh-bound executable so the AOT persistence layer skips
    ``jax.export`` for it (the serialized program would pin this
    process's device count/topology); see ``build_distributed_match``."""
    fn.aot_exportable = False
    return fn


def build_distributed_match_batch(Q_shape: Tuple[int, int], mesh: Mesh,
                                  cfg: pso.PSOConfig,
                                  axis_names: Sequence[str] = ("data",),
                                  batch: int = 1):
    """Returns a jit'd ``match(keys, Qb, Gb, maskb, carry0)`` solving a
    stacked batch of B problems on the mesh.

    ``keys`` is (B,) PRNG keys (one per problem); ``Qb``/``Gb``/``maskb``
    are stacked on the leading problem axis and ``carry0`` holds stacked
    per-problem warm-start carries. Two regimes:

      * **problem-axis sharding** (B ≥ devices and divisible): each device
        solves B/D whole problems locally — zero collectives, and each
        problem's result is bit-identical to the single-device path.
      * **per-problem particle sharding** (small B): falls back to the
        collective-fused ``build_distributed_match`` executed per problem
        (unrolled — B is static), stacking results on the problem axis.

    Output layout matches ``pso.match_batch`` (problem axis after the
    epoch axis on per-epoch leaves, leading elsewhere).
    """
    axis_names = tuple(axis_names)
    num_shards = int(np.prod([mesh.shape[a] for a in axis_names]))

    if batch >= num_shards and batch % num_shards == 0:
        def local_match(keys, Qb, Gb, maskb, carry0):
            return pso._match_batch_body(keys, Qb, Gb, maskb, cfg, carry0)

        shard_b = P(axis_names)
        in_specs = (shard_b, shard_b, shard_b, shard_b,
                    (shard_b, shard_b, shard_b))
        out_specs = dict(
            mappings=P(None, axis_names), feasible=P(None, axis_names),
            fitness=P(None, axis_names), f_star_trace=P(None, axis_names),
            S_star=shard_b, f_star=shard_b, S_bar=shard_b,
            epochs_run=shard_b, carry_mapping=shard_b,
            carry_feasible=shard_b, prune_sweeps=shard_b)
        shard_map = get_shard_map()
        fn = shard_map(local_match, mesh=mesh, in_specs=in_specs,
                       out_specs=out_specs)
        return _mark_mesh_executable(jax.jit(fn))

    per_problem = build_distributed_match(Q_shape, mesh, cfg, axis_names)
    per_epoch = ("mappings", "feasible", "fitness", "f_star_trace")

    def fn(keys, Qb, Gb, maskb, carry0):
        outs_list = []
        for b in range(batch):
            kb = jax.random.split(keys[b], num_shards)
            cb = jax.tree_util.tree_map(lambda x: x[b], carry0)
            outs_list.append(per_problem(kb, Qb[b], Gb[b], maskb[b], cb))
        return {k: jnp.stack([o[k] for o in outs_list],
                             axis=1 if k in per_epoch else 0)
                for k in outs_list[0]}

    return _mark_mesh_executable(jax.jit(fn))


def build_distributed_revalidate_batch(Q_shape: Tuple[int, int], mesh: Mesh,
                                       cfg: pso.PSOConfig,
                                       axis_names: Sequence[str] = ("data",),
                                       batch: int = 1):
    """Returns a jit'd ``revalidate(Qb, Gb, maskb, carry0)`` running the
    tiered pipeline's cheap stage (carry rebase + one structured
    projection + feasibility per problem) on the mesh.

    Revalidation has no swarm and no collectives, so the two regimes are
    both embarrassingly parallel:

      * **problem-axis sharding** (B ≥ devices and divisible): each device
        revalidates B/D carries locally;
      * **replicated fallback** (small B): every device computes the whole
        (tiny) batch — one projection per problem is far below the cost of
        re-sharding, and the replicated outputs keep the calling
        convention identical.
    """
    axis_names = tuple(axis_names)
    num_shards = int(np.prod([mesh.shape[a] for a in axis_names]))
    shard_map = get_shard_map()

    def local_reval(Qb, Gb, maskb, carry0):
        return pso._revalidate_batch_body(Qb, Gb, maskb, cfg, carry0)

    if batch >= num_shards and batch % num_shards == 0:
        shard_b = P(axis_names)
        in_specs = (shard_b, shard_b, shard_b,
                    (shard_b, shard_b, shard_b))
        out_specs = dict(mapping=shard_b, ok=shard_b, ok_rebase=shard_b,
                         fitness=shard_b, S_star=shard_b, S_bar=shard_b,
                         prune_sweeps=shard_b, f_carry=shard_b)
    else:
        in_specs = (P(), P(), P(), (P(), P(), P()))
        out_specs = dict(mapping=P(), ok=P(), ok_rebase=P(), fitness=P(),
                         S_star=P(), S_bar=P(), prune_sweeps=P(),
                         f_carry=P())
    fn = shard_map(local_reval, mesh=mesh, in_specs=in_specs,
                   out_specs=out_specs)
    return _mark_mesh_executable(jax.jit(fn))


class IMMSchedMatcher:
    """High-level matcher API.

    Single-device by default; pass a mesh + axis names for the sharded
    version (each mesh slice runs ``cfg.num_particles`` particles).
    """

    def __init__(self, cfg: Optional[pso.PSOConfig] = None,
                 mesh: Optional[Mesh] = None,
                 axis_names: Sequence[str] = ("data",)):
        self.cfg = cfg or pso.PSOConfig()
        self.mesh = mesh
        self.axis_names = tuple(axis_names)

    def match(self, query: Graph, target: Graph,
              key: Optional[jax.Array] = None,
              carry0=None) -> MatchResult:
        from repro.core.graphs import topological_relabel
        query, order = topological_relabel(query)
        self._order = order
        Q, G, mask = as_device_graphs(query, target)
        if key is None:
            key = jax.random.PRNGKey(0)
        if carry0 is None:
            carry0 = pso.default_carry(mask)
        if self.mesh is None:
            outs = pso.match(key, Q, G, mask, self.cfg, carry0)
        else:
            num_shards = int(np.prod([self.mesh.shape[a]
                                      for a in self.axis_names]))
            keys = jax.random.split(key, num_shards)
            fn = build_distributed_match(Q.shape, self.mesh, self.cfg,
                                         self.axis_names)
            outs = fn(keys, Q, G, mask, carry0)
        return self._collect(outs)

    def _collect(self, outs) -> MatchResult:
        return collect_result(outs, order=getattr(self, "_order", None))
