"""End-to-end driver: train a ~100M-parameter qwen-family LM for a few
hundred steps on CPU with checkpoint/restart.

    PYTHONPATH=src python examples/train_lm.py [--steps 200]

This wraps the production launcher (repro.launch.train) with a reduced
config: same family/topology as qwen1.5-0.5b, ~100M params, synthetic
deterministic data, AdamW, checkpointing every 50 steps. Kill it halfway
and run again — it resumes from the latest checkpoint.
"""
import argparse
import sys

from repro.launch.train import main as train_main


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--ckpt", default="/tmp/repro_train_lm_ckpt")
    args = ap.parse_args()
    return train_main([
        "--arch", "qwen1.5-0.5b", "--reduced",
        "--d-model", "768", "--layers", "10",
        "--steps", str(args.steps), "--batch", "8", "--seq", "256",
        "--checkpoint-dir", args.ckpt, "--checkpoint-every", "50",
    ])


if __name__ == "__main__":
    sys.exit(main())
