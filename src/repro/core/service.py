"""Online matcher service: warm-started, compile-cached subgraph matching.

``pso.match`` alone is a batch API: every new (n, m) query/target shape
triggers an XLA recompile (seconds) and every call restarts the swarm from
the cold uniform prior — the opposite of what an *online* scheduler needs
when tasks arrive unpredictably at microsecond granularity. The
``MatcherService`` turns it into a service:

  * **Shape classes** — query/target problems are bucketed to padded
    ``(n_pad, m_pad)`` classes via ``preemptible_dag.pad_problem`` (dummy
    tiles pinned to dummy PEs, semantics preserved), so repeat arrivals of
    any size within a bucket reuse one compiled executable.
  * **Bounded compile LRU** — one jit wrapper per (bucket, config), held in
    an LRU of ``cache_capacity`` entries; evicting an entry drops its
    executable. Repeat arrivals never recompile.
  * **Warm starts** — the final global-controller state
    ``(S*, f*, S̄)`` of each call is remembered under a
    (workload, platform-state) key and fed back as ``carry0`` on the next
    arrival of the same problem, so the swarm resumes from the previous
    consensus instead of the uniform prior.
  * **Early exit** — the service enables ``cfg.early_exit`` so easy
    matches stop scanning epochs once a feasible mapping clears the
    fitness bound (1 epoch instead of T on planted instances).
  * **Request coalescing** — concurrent arrivals queue via ``submit`` and
    ``drain`` flushes every same-bucket request in one *batched* launch
    (``pso.match_batch``): K problems in an event window pay one jit
    dispatch and one swarm warm-up instead of K. Batch size is padded to
    a small set of classes (``batch_classes``, default 1/2/4/8) that
    joins the compile-cache key, so the executable set stays bounded;
    per-problem warm-start carries are gathered before and scattered
    after the launch. Per-problem early exit keeps each problem's
    *results* and epoch accounting identical to a solo call, but the
    launch's wall time is that of its hardest member — every request in
    the batch is charged the same ``latency_s`` (coalesce warm/servable
    traffic; a mixed cold burst can be slower than sequential).

Statistics for all four mechanisms are exported via ``stats`` /
``stats_dict()`` and surfaced by ``sched.metrics``.
"""
from __future__ import annotations

import dataclasses
import hashlib
import time
from collections import OrderedDict
from typing import Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import pso
from repro.core.graphs import (Graph, compatibility_mask,
                               topological_relabel)
from repro.core.matcher import (MatchResult, build_distributed_match,
                                build_distributed_match_batch,
                                collect_batch_results, collect_result)
from repro.core.preemptible_dag import pad_problem


def _round_up(v: int, mult: int) -> int:
    mult = max(mult, 1)
    return ((v + mult - 1) // mult) * mult


def shape_bucket(n: int, m: int, n_multiple: int = 8,
                 m_multiple: int = 16) -> Tuple[int, int]:
    """Stable padded shape class for an (n, m) matching problem.

    The target bucket must leave room for the ``n_pad - n`` dummy PEs that
    ``pad_problem`` pins the dummy query tiles to.
    """
    n_pad = _round_up(max(n, 1), n_multiple)
    m_pad = _round_up(max(m, 1) + (n_pad - n), m_multiple)
    return n_pad, m_pad


@dataclasses.dataclass
class ServiceStats:
    calls: int = 0
    compile_cache_hits: int = 0      # bucket already had an executable
    compile_cache_misses: int = 0    # new bucket → jit compile
    compile_evictions: int = 0
    warm_hits: int = 0               # carry0 reused from a previous call
    warm_misses: int = 0
    warm_evictions: int = 0
    epochs_run: int = 0              # total epochs actually executed
    epochs_budgeted: int = 0         # cfg.epochs × calls
    found: int = 0
    batch_launches: int = 0          # batched executions dispatched
    coalesced_requests: int = 0      # requests served in a shared launch
    batch_problems: int = 0          # real problems through the batch path
    batch_slots: int = 0             # padded batch slots launched
    carry_fastpath_hits: int = 0     # warm carries re-validated, 0 epochs

    @property
    def epochs_saved(self) -> int:
        return self.epochs_budgeted - self.epochs_run

    @property
    def compile_hit_rate(self) -> float:
        return self.compile_cache_hits / max(self.calls, 1)

    @property
    def warm_hit_rate(self) -> float:
        return self.warm_hits / max(self.calls, 1)

    @property
    def batch_occupancy(self) -> float:
        """Real problems per launched batch slot (1.0 = no padding waste)."""
        return self.batch_problems / max(self.batch_slots, 1)


@dataclasses.dataclass
class ServiceMatchResult(MatchResult):
    bucket: Tuple[int, int] = (0, 0)
    compile_cache_hit: bool = False
    warm_hit: bool = False
    latency_s: float = 0.0           # launch wall time (shared by a batch)
    batch_size: int = 1              # real problems in the launch
    coalesced: bool = False          # served together with other requests


@dataclasses.dataclass
class _PendingRequest:
    """A submitted problem, pre-padded to its shape bucket so ``drain``
    can group by bucket without touching the graphs again."""
    key: jax.Array
    workload_key: object
    order: np.ndarray
    crop: Tuple[int, int]
    bucket: Tuple[int, int]
    Qp: np.ndarray
    Gp: np.ndarray
    maskp: np.ndarray


class MatcherService:
    """Warm-start online wrapper around Algorithm 1.

    Single-device by default; pass ``mesh`` + ``axis_names`` to run each
    bucket's executable as the collective-fused distributed matcher.
    """

    def __init__(self, cfg: Optional[pso.PSOConfig] = None, *,
                 mesh=None, axis_names: Sequence[str] = ("data",),
                 cache_capacity: int = 16, warm_capacity: int = 256,
                 warm_start: bool = True, early_exit: bool = True,
                 n_multiple: int = 8, m_multiple: int = 16,
                 batch_classes: Sequence[int] = (1, 2, 4, 8)):
        cfg = cfg or pso.PSOConfig()
        if early_exit and not cfg.early_exit:
            cfg = cfg.replace(early_exit=True)
        self.cfg = cfg
        self.mesh = mesh
        self.axis_names = tuple(axis_names)
        self.cache_capacity = max(int(cache_capacity), 1)
        self.warm_capacity = max(int(warm_capacity), 1)
        self.warm_start = warm_start
        self.n_multiple = n_multiple
        self.m_multiple = m_multiple
        self.batch_classes = tuple(sorted(set(int(b) for b in batch_classes)))
        assert self.batch_classes and self.batch_classes[0] >= 1
        self.stats = ServiceStats()
        self._compiled: "OrderedDict[Tuple, object]" = OrderedDict()
        self._warm: "OrderedDict[Tuple, tuple]" = OrderedDict()
        self._pending: List[_PendingRequest] = []

    # -- caches ------------------------------------------------------------

    def _cache_put(self, cache_key, fn):
        self._compiled[cache_key] = fn
        while len(self._compiled) > self.cache_capacity:
            self._compiled.popitem(last=False)
            self.stats.compile_evictions += 1
        return fn

    def _cache_get(self, cache_key):
        fn = self._compiled.get(cache_key)
        if fn is not None:
            self._compiled.move_to_end(cache_key)
            self.stats.compile_cache_hits += 1
        return fn

    def _executable(self, bucket: Tuple[int, int]):
        fn = self._cache_get(bucket)
        if fn is not None:
            return fn
        self.stats.compile_cache_misses += 1
        if self.mesh is None:
            cfg = self.cfg

            def fn(key, Q, G, mask, carry0, _cfg=cfg):
                return pso._match_body(key, Q, G, mask, _cfg, carry0)

            fn = jax.jit(fn)
        else:
            fn = build_distributed_match(bucket, self.mesh, self.cfg,
                                         self.axis_names)
        return self._cache_put(bucket, fn)

    def _executable_batch(self, bucket: Tuple[int, int], bclass: int):
        """One executable per (shape bucket, padded batch class)."""
        cache_key = (bucket, bclass)
        fn = self._cache_get(cache_key)
        if fn is not None:
            return fn
        self.stats.compile_cache_misses += 1
        if self.mesh is None:
            cfg = self.cfg

            def fn(keys, Qb, Gb, maskb, carry0, _cfg=cfg):
                return pso._match_batch_body(keys, Qb, Gb, maskb, _cfg,
                                             carry0)

            fn = jax.jit(fn)
        else:
            fn = build_distributed_match_batch(bucket, self.mesh, self.cfg,
                                               self.axis_names, bclass)
        return self._cache_put(cache_key, fn)

    def _batch_class(self, k: int) -> int:
        """Smallest padded batch class holding k problems."""
        for c in self.batch_classes:
            if c >= k:
                return c
        return self.batch_classes[-1]

    def _warm_key(self, workload_key, Qp, Gp, maskp) -> Tuple:
        """Warm starts are only valid for the *same* problem (f* values are
        not comparable across different Q/G), so the key always includes a
        content digest; ``workload_key`` additionally scopes entries to the
        caller's (workload, platform-state) naming."""
        h = hashlib.sha1()
        h.update(np.ascontiguousarray(Qp).tobytes())
        h.update(np.ascontiguousarray(Gp).tobytes())
        h.update(np.ascontiguousarray(maskp).tobytes())
        return (workload_key, Qp.shape[0], Gp.shape[0], h.hexdigest())

    def _get_carry(self, warm_key):
        if self.warm_start and warm_key in self._warm:
            self._warm.move_to_end(warm_key)
            self.stats.warm_hits += 1
            return self._warm[warm_key], True
        self.stats.warm_misses += 1
        return None, False

    def _put_carry(self, warm_key, carry):
        if not self.warm_start:
            return
        self._warm[warm_key] = carry
        while len(self._warm) > self.warm_capacity:
            self._warm.popitem(last=False)
            self.stats.warm_evictions += 1

    # -- matching ----------------------------------------------------------

    def _prepare(self, query: Graph, target: Graph, key, workload_key
                 ) -> _PendingRequest:
        """Relabel, bucket and pad a problem on the host — the jit call
        uploads Qp/Gp/maskp once; no device→host→device round trip."""
        if key is None:
            key = jax.random.PRNGKey(0)
        q, order = topological_relabel(query)
        n, m = q.n, target.n
        mask = compatibility_mask(q, target)
        bucket = shape_bucket(n, m, self.n_multiple, self.m_multiple)
        Qp, Gp, maskp = pad_problem(q.adj, target.adj, mask, *bucket)
        return _PendingRequest(key=key, workload_key=workload_key,
                               order=order, crop=(n, m), bucket=bucket,
                               Qp=Qp, Gp=Gp, maskp=maskp)

    def match(self, query: Graph, target: Graph,
              key: Optional[jax.Array] = None,
              workload_key=None) -> ServiceMatchResult:
        """Match ``query`` onto ``target`` through the service caches.

        ``workload_key`` names the (workload, platform-state) class for
        warm-start scoping — e.g. ``(task_name, free_engine_signature)``.
        Results are exactly the unpadded equivalent of a direct
        ``pso.match`` on the same problem.
        """
        t0 = time.perf_counter()
        self.stats.calls += 1
        req = self._prepare(query, target, key, workload_key)
        key, bucket = req.key, req.bucket
        order, (n, m) = req.order, req.crop
        Qp, Gp, maskp = req.Qp, req.Gp, req.maskp

        hits_before = self.stats.compile_cache_hits
        fn = self._executable(bucket)
        compile_hit = self.stats.compile_cache_hits > hits_before

        warm_key = self._warm_key(workload_key, Qp, Gp, maskp)
        carry0, warm_hit = self._get_carry(warm_key)
        if carry0 is None:
            carry0 = pso.default_carry(jnp.asarray(maskp))

        if self.mesh is None:
            outs = fn(key, Qp, Gp, maskp, carry0)
        else:
            num_shards = int(np.prod([self.mesh.shape[a]
                                      for a in self.axis_names]))
            keys = jax.random.split(key, num_shards)
            outs = fn(keys, Qp, Gp, maskp, carry0)

        base = collect_result(outs, order=order, crop=(n, m))
        res = ServiceMatchResult(**{f.name: getattr(base, f.name)
                                    for f in dataclasses.fields(MatchResult)})
        self._put_carry(warm_key, res.carry)
        self.stats.epochs_run += res.epochs_run
        self.stats.epochs_budgeted += self.cfg.epochs
        if res.found:
            self.stats.found += 1
        if res.carry_verified:
            self.stats.carry_fastpath_hits += 1
        res.bucket = bucket
        res.compile_cache_hit = compile_hit
        res.warm_hit = warm_hit
        res.latency_s = time.perf_counter() - t0
        return res

    # -- request coalescing ------------------------------------------------

    def submit(self, query: Graph, target: Graph,
               key: Optional[jax.Array] = None, workload_key=None) -> int:
        """Queue a problem for the next ``drain``; returns its ticket
        index into the results list ``drain`` will return."""
        self._pending.append(self._prepare(query, target, key, workload_key))
        return len(self._pending) - 1

    @property
    def pending(self) -> int:
        return len(self._pending)

    def drain(self) -> List[ServiceMatchResult]:
        """Flush the pending queue: all same-bucket requests coalesce into
        padded batch launches (one jit dispatch each), largest batch class
        first. Results come back in submission order; every request in a
        launch reports the same ``latency_s`` (the batch is one decision —
        its cost is paid once, not per problem)."""
        pending, self._pending = self._pending, []
        if not pending:
            return []
        results: List[Optional[ServiceMatchResult]] = [None] * len(pending)
        groups: "OrderedDict[Tuple[int, int], List[int]]" = OrderedDict()
        for i, req in enumerate(pending):
            groups.setdefault(req.bucket, []).append(i)
        max_chunk = self.batch_classes[-1]
        for bucket, idxs in groups.items():
            for pos in range(0, len(idxs), max_chunk):
                chunk = idxs[pos:pos + max_chunk]
                self._launch_batch(bucket, [pending[i] for i in chunk],
                                   chunk, results)
        return results  # type: ignore[return-value]

    def match_many(self, problems: Sequence[Tuple[Graph, Graph]],
                   keys: Optional[Sequence[jax.Array]] = None,
                   workload_keys: Optional[Sequence] = None
                   ) -> List[ServiceMatchResult]:
        """Convenience: submit a burst of (query, target) problems and
        drain them as coalesced batch launches."""
        for i, (q, g) in enumerate(problems):
            self.submit(q, g,
                        key=None if keys is None else keys[i],
                        workload_key=(None if workload_keys is None
                                      else workload_keys[i]))
        return self.drain()

    def _launch_batch(self, bucket, reqs: List[_PendingRequest],
                      tickets: List[int], results: List) -> None:
        """One coalesced launch: gather per-problem warm carries, pad the
        problem stack to the batch class, run, scatter results+carries."""
        t0 = time.perf_counter()
        B = len(reqs)
        bclass = self._batch_class(B)
        self.stats.calls += B

        hits_before = self.stats.compile_cache_hits
        fn = self._executable_batch(bucket, bclass)
        compile_hit = self.stats.compile_cache_hits > hits_before

        warm_keys, carries, warm_hits = [], [], []
        for req in reqs:
            wk = self._warm_key(req.workload_key, req.Qp, req.Gp, req.maskp)
            carry, hit = self._get_carry(wk)
            if carry is None:
                carry = pso.default_carry(jnp.asarray(req.maskp))
            warm_keys.append(wk)
            carries.append(carry)
            warm_hits.append(hit)

        # pad the stack to the batch class by replicating problem 0
        # verbatim — same key AND same carry, so every pad slot follows
        # problem 0's exact trajectory and is done the instant it is:
        # padding never extends the batch's live-epoch window (its only
        # cost is the slot width). Results are discarded.
        # All stacking stays on the host (numpy): the jit call uploads each
        # stacked array once — no per-problem device dispatches.
        pad = bclass - B
        padded = reqs + [reqs[0]] * pad
        carries = carries + [carries[0]] * pad
        keysb = np.stack([np.asarray(r.key) for r in padded])
        Qb = np.stack([r.Qp for r in padded])
        Gb = np.stack([r.Gp for r in padded])
        maskb = np.stack([r.maskp for r in padded])
        carry0 = tuple(np.stack([np.asarray(c[i]) for c in carries])
                       for i in range(3))

        outs = fn(keysb, Qb, Gb, maskb, carry0)
        batch_results = collect_batch_results(
            outs, bclass,
            orders=[r.order for r in padded],
            crops=[r.crop for r in padded])
        latency = time.perf_counter() - t0

        self.stats.batch_launches += 1
        self.stats.batch_problems += B
        self.stats.batch_slots += bclass
        if B > 1:
            self.stats.coalesced_requests += B
        for j, (req, ticket) in enumerate(zip(reqs, tickets)):
            base = batch_results[j]
            res = ServiceMatchResult(
                **{f.name: getattr(base, f.name)
                   for f in dataclasses.fields(MatchResult)})
            self._put_carry(warm_keys[j], res.carry)
            self.stats.epochs_run += res.epochs_run
            self.stats.epochs_budgeted += self.cfg.epochs
            if res.found:
                self.stats.found += 1
            if res.carry_verified:
                self.stats.carry_fastpath_hits += 1
            res.bucket = bucket
            res.compile_cache_hit = compile_hit
            res.warm_hit = warm_hits[j]
            res.latency_s = latency
            res.batch_size = B
            res.coalesced = B > 1
            results[ticket] = res

    # -- reporting ---------------------------------------------------------

    def stats_dict(self) -> Dict[str, float]:
        s = self.stats
        return {
            "calls": s.calls,
            "compile_cache_hits": s.compile_cache_hits,
            "compile_cache_misses": s.compile_cache_misses,
            "compile_hit_rate": s.compile_hit_rate,
            "warm_hits": s.warm_hits,
            "warm_misses": s.warm_misses,
            "warm_hit_rate": s.warm_hit_rate,
            "epochs_run": s.epochs_run,
            "epochs_budgeted": s.epochs_budgeted,
            "epochs_saved": s.epochs_saved,
            "found": s.found,
            "batch_launches": s.batch_launches,
            "coalesced_requests": s.coalesced_requests,
            "batch_problems": s.batch_problems,
            "batch_slots": s.batch_slots,
            "batch_occupancy": s.batch_occupancy,
            "carry_fastpath_hits": s.carry_fastpath_hits,
        }
