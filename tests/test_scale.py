"""Streaming event loop: legacy-loop equivalence, streamed-scenario
identity, global engine occupancy, truncation signalling, and
property-based event-loop invariants."""
import dataclasses

import numpy as np
import pytest

from _hyp_compat import given, settings, st
from repro.accel import EDGE
from repro.sched import (SimConfig, Simulator, get_scheduler,
                         make_burst_scenario, make_scenario,
                         make_streaming_scenario)
from repro.sched.metrics import latency_bound_throughput
from repro.sched.tasks import (StreamScenario, fixed_scenario,
                               make_restart_scenario)
from repro.workloads import workload_complexity_class


def _cfg(**kw) -> SimConfig:
    return SimConfig(platform=EDGE, matcher_mode="analytic", **kw)


def _result_diff(a, b):
    da, db = dataclasses.asdict(a), dataclasses.asdict(b)
    return {k: (da[k], db[k]) for k in da if da[k] != db[k]}


# -- streamed scenarios reproduce the materialized ones -----------------

def test_streaming_scenario_specs_byte_identical():
    kw = dict(rate_hz=80.0, horizon=1.0, urgent_frac=0.3,
              burst_size=4, burst_frac=0.5, seed=17)
    listed = make_scenario("simple", **kw).tasks
    streamed = list(make_streaming_scenario("simple", **kw).arrivals_iter())
    assert len(listed) == len(streamed)
    for a, b in zip(listed, streamed):
        assert (a.name, a.arrival, a.deadline, a.priority, a.urgent) \
            == (b.name, b.arrival, b.deadline, b.priority, b.urgent)


def test_streaming_scenario_replayable():
    ss = make_streaming_scenario("simple", rate_hz=50.0, seed=3)
    first = [t.arrival for t in ss.arrivals_iter()]
    second = [t.arrival for t in ss.arrivals_iter()]
    assert first and first == second


def test_streaming_run_matches_list_run():
    kw = dict(rate_hz=50.0, horizon=1.0, seed=7)
    r_list = Simulator(_cfg(), get_scheduler("immsched")).run(
        make_scenario("simple", **kw))
    r_stream = Simulator(_cfg(), get_scheduler("immsched")).run(
        make_streaming_scenario("simple", **kw))
    assert not _result_diff(r_list, r_stream)
    assert r_stream.finished == r_stream.total > 0


# -- heap loop is bitwise-equal to the legacy scan loop -----------------

@pytest.mark.parametrize("name", ["immsched", "isosched", "prema",
                                  "planaria", "moca", "cdmsa"])
def test_heap_loop_bitwise_equal_legacy(name):
    sc = make_scenario("simple", rate_hz=40.0, horizon=1.0, seed=1)
    a = Simulator(_cfg(), get_scheduler(name)).run(sc)
    b = Simulator(_cfg(), get_scheduler(name)).run_legacy(sc)
    assert not _result_diff(a, b)


@pytest.mark.parametrize("scenario", [
    make_burst_scenario("simple", rate_hz=20.0, horizon=1.0, seed=2),
    make_restart_scenario(seed=3),
    fixed_scenario(workload_complexity_class("simple")[:4]),
])
def test_heap_loop_equal_on_scenario_shapes(scenario):
    a = Simulator(_cfg(), get_scheduler("immsched")).run(scenario)
    b = Simulator(_cfg(), get_scheduler("immsched")).run_legacy(scenario)
    assert not _result_diff(a, b)


# -- bugfix: global engine occupancy ------------------------------------

class _DoubleBookingScheduler:
    """Hostile scheduler: hands the SAME two engines to every ready task
    — the double-booking decision the simulator must refuse."""
    name = "conflict"
    paradigm = "tss"

    def reset(self, sim):
        pass

    def on_restart(self, sim, now):
        pass

    def matcher_stats(self):
        return {}

    def on_event(self, sim, now, tasks, trigger, arrived=None):
        dec = {"alloc": {}, "preempt": [], "delay": {}, "energy": 0.0}
        for t in tasks:
            if t.status == "ready":
                dec["alloc"][t.spec.task_id] = [0, 1]
        return dec


@pytest.mark.parametrize("loop", ["run", "run_legacy"])
def test_engine_double_booking_refused(loop):
    # spacing far below the execution time, so later arrivals ask for
    # engines the first claimant still holds
    sc = fixed_scenario(workload_complexity_class("simple")[:3],
                        urgent_last=False, spacing=1e-6)
    sim = Simulator(_cfg(validate=True), _DoubleBookingScheduler())
    r = getattr(sim, loop)(sc)
    # first claimant keeps engines {0, 1}; conflicting allocs are
    # filtered and counted instead of silently double-booking
    assert r.alloc_conflicts >= 2
    # occupancy held: never more than the 2 granted engines busy
    assert r.busy_integral <= 2 * r.sim_horizon + 1e-9


def test_well_behaved_schedulers_have_no_conflicts():
    sc = make_scenario("simple", rate_hz=60.0, horizon=1.0, seed=9)
    for name in ("immsched", "prema"):
        r = Simulator(_cfg(validate=True), get_scheduler(name)).run(sc)
        assert r.alloc_conflicts == 0


# -- bugfix: event-budget truncation is loud ----------------------------

@pytest.mark.parametrize("loop", ["run", "run_legacy"])
def test_truncation_flag_set_when_budget_exhausted(loop):
    sc = make_scenario("simple", rate_hz=60.0, horizon=1.0, seed=4)
    sim = Simulator(_cfg(max_events=3), get_scheduler("immsched"))
    r = getattr(sim, loop)(sc)
    assert r.truncated
    assert r.events == 3


def test_truncation_flag_clear_on_completed_run():
    sc = make_scenario("simple", rate_hz=60.0, horizon=1.0, seed=4)
    r = Simulator(_cfg(), get_scheduler("immsched")).run(sc)
    assert not r.truncated
    assert r.events > 0
    r2 = Simulator(_cfg(max_events=None),
                   get_scheduler("immsched")).run(sc)
    assert not r2.truncated and r2.events == r.events


# -- bugfix: LBT lower-bound branch -------------------------------------

def test_lbt_returns_zero_when_even_lo_unsustainable():
    # an unreachable hit target fails at every rate: the old code
    # reported `lo` itself as the max sustainable rate
    rate = latency_bound_throughput(
        "immsched", EDGE, "simple", hit_target=1.01,
        horizon=0.05, lo=200.0, hi=400.0, iters=1)
    assert rate == 0.0


def test_lbt_returns_at_least_lo_when_lo_sustainable():
    rate = latency_bound_throughput(
        "immsched", EDGE, "simple", hit_target=0.0,
        horizon=0.05, lo=20.0, hi=80.0, iters=2)
    assert rate >= 20.0


# -- property-based event-loop invariants -------------------------------

@settings(max_examples=5, deadline=None)
@given(st.integers(0, 10_000), st.integers(20, 80))
def test_streamed_event_loop_invariants(seed, rate):
    """validate=True makes the loop assert per event that no engine is
    double-booked and busy_integral <= engines * now; on top, check the
    result-level invariants on a random streamed scenario."""
    ss = make_streaming_scenario("simple", rate_hz=float(rate),
                                 horizon=0.5, seed=seed)
    r = Simulator(_cfg(validate=True), get_scheduler("immsched")).run(ss)
    assert not r.truncated
    assert r.finished <= r.total
    assert r.alloc_conflicts == 0
    assert r.busy_integral <= EDGE.engines * r.sim_horizon + 1e-9
    if r.percentiles:
        p = r.percentiles
        assert p["latency_p50"] <= p["latency_p99"] <= p["latency_p999"]
        assert p["sched_p50"] <= p["sched_p99"] <= p["sched_p999"]
        # percentile support: every finished task waited >= 0
        assert p["latency_p50"] >= 0.0


@settings(max_examples=4, deadline=None)
@given(st.integers(0, 10_000))
def test_heap_equals_legacy_on_random_scenarios(seed):
    sc = make_scenario("simple", rate_hz=30.0, horizon=0.6, seed=seed)
    for name in ("immsched", "prema"):
        a = Simulator(_cfg(), get_scheduler(name)).run(sc)
        b = Simulator(_cfg(), get_scheduler(name)).run_legacy(sc)
        assert not _result_diff(a, b)


# -- streaming keeps memory bounded -------------------------------------

def test_live_table_stays_small_on_long_stream():
    """A long under-loaded stream must not accumulate tasks: the live
    table peaks near the concurrency the platform sustains, orders of
    magnitude below the arrival count."""
    ss = make_streaming_scenario("simple", rate_hz=400.0, horizon=10.0,
                                 seed=13)
    r = Simulator(_cfg(max_events=None),
                  get_scheduler("immsched")).run(ss)
    assert r.total > 3_000
    assert r.finished == r.total
    assert r.peak_live_tasks < 100


def test_stream_scenario_expected_arrivals_estimate():
    ss = make_streaming_scenario("simple", rate_hz=100.0, horizon=2.0,
                                 seed=5)
    assert isinstance(ss, StreamScenario)
    n = sum(1 for _ in ss.arrivals_iter())
    assert ss.expected_arrivals == 200
    assert abs(n - ss.expected_arrivals) < 100
